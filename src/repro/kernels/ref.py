"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Deliberately written in the most obvious way possible — masked full
softmax, dense dequant matmul, step-by-step SSD recurrence — so the
kernels are validated against independent math, not a refactor of
themselves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_matmul_ref(x, wq, scale, out_dtype=jnp.bfloat16):
    """x (M,K) @ dequant(wq (K,N) int8, scale (N,))."""
    w = wq.astype(jnp.float32) * scale[None, :].astype(jnp.float32)
    out = jnp.dot(x.astype(jnp.float32), w)
    return out.astype(out_dtype)


def flash_attention_ref(q, k, v, *, scale, window: int = 0,
                        softcap: float = 0.0):
    """q (B,S,H,hd); k,v (B,T,K,hd). Masked full-softmax attention."""
    B, S, H, hd = q.shape
    _, T, Kh, _ = k.shape
    G = H // Kh
    qg = q.reshape(B, S, Kh, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, kf) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        scale, softcap: float = 0.0,
                        k_scale=None, v_scale=None):
    """Gather-based paged-attention decode read (the obvious way).

    q (B,H,hd) one query token per sequence; k_pages/v_pages
    (num_blocks, bs, K, hd) shared page pool; block_tables (B, n_blk)
    int32 physical ids (-1 = unallocated); lengths (B,) valid context
    token counts — row b attends logical positions [0, lengths[b]).
    ``k_scale``/``v_scale`` (num_blocks, bs, K): per-(page, offset,
    kv-head) dequant scales for an int8 pool — the gathered pages are
    dequantized densely before the softmax (the f32-materialising twin
    of the fused kernel read).  Returns (B, H, hd).
    """
    Bq, H, hd = q.shape
    nB, bs, Kh, _ = k_pages.shape
    G = H // Kh
    bt = jnp.clip(block_tables, 0, nB - 1)
    kg = k_pages[bt].reshape(Bq, -1, Kh, hd).astype(jnp.float32)
    vg = v_pages[bt].reshape(Bq, -1, Kh, hd).astype(jnp.float32)
    if k_scale is not None:
        kg = kg * k_scale[bt].reshape(Bq, -1, Kh)[..., None].astype(jnp.float32)
        vg = vg * v_scale[bt].reshape(Bq, -1, Kh)[..., None].astype(jnp.float32)
    qg = q.reshape(Bq, Kh, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, kg) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    t = jnp.arange(kg.shape[1])
    valid = (t[None, :] < lengths[:, None]) \
        & jnp.repeat(block_tables >= 0, bs, axis=1)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, vg)
    return out.reshape(Bq, H, hd).astype(q.dtype)


def paged_extend_attention_ref(q, k_pages, v_pages, k_new, v_new,
                               block_tables, pos, *, scale,
                               softcap: float = 0.0,
                               k_scale=None, v_scale=None):
    """Gather-based multi-token extend read (the obvious way).

    q (B,S,H,hd): S new tokens per row at absolute positions
    ``pos + i``; k_new/v_new (B,S,K,hd): the suffix K/V those tokens
    attend causally (already round-tripped by the caller on a quantized
    pool); k_pages/v_pages (num_blocks, bs, K, hd) with optional
    per-(page, offset, kv-head) ``k_scale``/``v_scale``; block_tables
    (B, n_blk); pos (B,) — context positions ``< pos`` are visible,
    everything at or beyond ``pos`` (stale speculation) is masked.
    Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    nB, bs, Kh, _ = k_pages.shape
    G = H // Kh
    bt = jnp.clip(block_tables, 0, nB - 1)
    kg = k_pages[bt].reshape(B, -1, Kh, hd).astype(jnp.float32)
    vg = v_pages[bt].reshape(B, -1, Kh, hd).astype(jnp.float32)
    if k_scale is not None:
        kg = kg * k_scale[bt].reshape(B, -1, Kh)[..., None].astype(jnp.float32)
        vg = vg * v_scale[bt].reshape(B, -1, Kh)[..., None].astype(jnp.float32)
    L = kg.shape[1]
    k_all = jnp.concatenate([kg, k_new.astype(jnp.float32)], axis=1)
    v_all = jnp.concatenate([vg, v_new.astype(jnp.float32)], axis=1)
    qg = q.reshape(B, S, Kh, G, hd).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k_all) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    t = jnp.arange(L)
    ctx_ok = (t[None, :] < pos[:, None]) \
        & jnp.repeat(block_tables >= 0, bs, axis=1)              # (B, L)
    causal = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]    # (S, S)
    mask = jnp.concatenate(
        [jnp.broadcast_to(ctx_ok[:, None, :], (B, S, L)),
         jnp.broadcast_to(causal, (B, S, S))], axis=-1)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v_all)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C, h0=None):
    """Naive sequential SSD recurrence (the definition, O(L) steps).

    x (b,l,h,p); dt (b,l,h); A (h,); B,C (b,l,n); h0 (b,h,p,n)|None.
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    hs = (jnp.zeros((b, h, p, n), jnp.float32) if h0 is None
          else h0.astype(jnp.float32))
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(hstate, inp):
        xt, dtt, Bt, Ct = inp
        a = jnp.exp(dtt * Af)                          # (b,h)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, Bt)
        hstate = hstate * a[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", hstate, Ct)
        return hstate, y

    hs, ys = jax.lax.scan(
        step, hs,
        (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
         Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hs
