"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU-native layout of the state-space-duality algorithm: the grid is
(batch, heads, chunks) with chunks INNERMOST — Pallas TPU grids execute
sequentially, so the (P, N) recurrent state lives in VMEM scratch across
chunk steps, exactly the HBM->VMEM residency the SSD recurrence wants.
Per chunk: intra-chunk quadratic term on the MXU, state emit/consume as
two more (Q,·)x(·,·) matmuls.  Replaces the GPU warp-parallel scan with
a VMEM-resident sequential chunk walk (DESIGN.md §Hardware adaptation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
            y_ref, hout_ref, state_scr, *, nc: int, Q: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = h0_ref[0, 0].astype(jnp.float32)       # (P, N)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, 0, 0, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0, 0, 0, 0].astype(jnp.float32)    # scalar for this head
    Bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)

    dA = dt * A                                  # (Q,)
    cums = jnp.cumsum(dA)                        # (Q,)
    xdt = x * dt[:, None]                        # (Q, P)

    # intra-chunk: (C B^T ∘ L) @ (x dt)
    seg = cums[:, None] - cums[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jnp.dot(scores * Lmat, xdt, preferred_element_type=jnp.float32)

    # inter-chunk: C_t exp(cums_t) . h_prev
    state = state_scr[...]                       # (P, N)
    Cs = Cm * jnp.exp(cums)[:, None]
    y += jax.lax.dot_general(Cs, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: h <- exp(cums_Q) h + (x dt decay_to_end)^T B
    decay_end = jnp.exp(cums[Q - 1] - cums)      # (Q,)
    contrib = jax.lax.dot_general(xdt * decay_end[:, None], Bm,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(cums[Q - 1]) + contrib

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(c_idx == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = state_scr[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, h0=None,
             interpret: bool = False):
    """SSD scan.  x: (b, l, h, p); dt: (b, l, h) (post-softplus);
    A: (h,) negative; B, C: (b, l, n); h0: (b, h, p, n) or None.

    Returns (y (b, l, h, p), h_final (b, h, p, n)).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, l)
    pad = (-l) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    L = l + pad
    nc = L // Q
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    # TPU-friendly layouts
    xr = x.transpose(0, 2, 1, 3).reshape(b, h, nc, Q, p)
    dtr = dt.transpose(0, 2, 1).reshape(b, h, nc, 1, Q)
    ar = jnp.broadcast_to(A.reshape(1, h), (b, h)).reshape(b, h, 1, 1)
    br = B.reshape(b, nc, Q, n)
    cr = C.reshape(b, nc, Q, n)

    grid = (b, h, nc)
    y, hout = pl.pallas_call(
        functools.partial(_kernel, nc=nc, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, Q), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda i, j, c: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, Q, n), lambda i, j, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, n), lambda i, j, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, Q, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, ar, br, cr, h0)

    y = y.reshape(b, h, L, p).transpose(0, 2, 1, 3)[:, :l]
    return y, hout
