"""Weight-quantized matmul Pallas TPU kernel (int8 / int4-range weights).

The paper's edge-LLM claim ("running a 4-bit quantised Llama-2-7B ...")
made TPU-native: weights live in HBM as int8 (int4 uses the int8
container with values in [-8, 7]; sub-byte packing is a storage-layer
concern, the roofline prices the bits), are DMA'd per (bk, bn) VMEM
block, dequantized in VREGs against per-output-channel scales and fed to
the MXU in bf16.  This replaces the GPU per-warp dequant idiom with a
per-VMEM-block dequant (DESIGN.md §Hardware adaptation).

Grid: (M/bm, N/bn, K/bk), K innermost so the f32 accumulator scratch
persists across the contraction.  Tiles are MXU-aligned (multiples of
128 on the lane dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wq_ref, scale_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.bfloat16)            # (bm, bk)
    w = wq_ref[...].astype(jnp.bfloat16)           # (bk, bn) dequant in VREG
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finalize():
        scale = scale_ref[...].astype(jnp.float32)  # (1, bn) per-channel
        o_ref[...] = (acc_ref[...] * scale).astype(o_ref.dtype)


def _pick_block(n: int, candidates=(512, 256, 128, 64, 32, 16, 8)) -> int:
    for c in candidates:
        if n % c == 0:
            return c
    return n


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def quant_matmul(x: jnp.ndarray, wq: jnp.ndarray, scale: jnp.ndarray, *,
                 interpret: bool = False, out_dtype=jnp.bfloat16):
    """x: (M, K) float; wq: (K, N) int8; scale: (N,) f32 per out channel.

    Returns (M, N) ``out_dtype`` ~= x @ (wq * scale).
    """
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2 and scale.shape == (N,)
    bm, bk, bn = _pick_block(M), _pick_block(K), _pick_block(N)
    grid = (M // bm, N // bn, K // bk)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, wq, scale.reshape(1, N))


def quantize_weights(w: jnp.ndarray, bits: int = 8):
    """Per-output-channel symmetric quantization of (K, N) weights."""
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(w), axis=0) / qmax + 1e-12     # (N,)
    q = jnp.clip(jnp.round(w / scale[None, :]), -qmax - 1, qmax)
    return q.astype(jnp.int8), scale.astype(jnp.float32)
