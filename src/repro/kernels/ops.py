"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the
kernel body executes step-by-step in Python, validating BlockSpec
indexing and the numerics.  On TPU backends they compile for real.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import quant_matmul as _qm
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def quant_matmul(x, wq, scale, out_dtype=jnp.bfloat16):
    return _qm.quant_matmul(x, wq, scale, out_dtype=out_dtype,
                            interpret=_interpret())


quantize_weights = _qm.quantize_weights


def flash_attention(q, k, v, *, scale, window: int = 0, softcap: float = 0.0):
    return _fa.flash_attention(q, k, v, scale=scale, window=window,
                               softcap=softcap, interpret=_interpret())


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *, scale,
                    softcap: float = 0.0, k_scale=None, v_scale=None):
    return _fa.paged_attention(q, k_pages, v_pages, block_tables, lengths,
                               scale=scale, softcap=softcap,
                               k_scale=k_scale, v_scale=v_scale,
                               interpret=_interpret())


def paged_extend_attention(q, k_pages, v_pages, k_new, v_new, block_tables,
                           pos, *, scale, softcap: float = 0.0,
                           k_scale=None, v_scale=None):
    return _fa.paged_extend_attention(q, k_pages, v_pages, k_new, v_new,
                                      block_tables, pos, scale=scale,
                                      softcap=softcap, k_scale=k_scale,
                                      v_scale=v_scale,
                                      interpret=_interpret())


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, h0=None):
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, h0=h0,
                         interpret=_interpret())
