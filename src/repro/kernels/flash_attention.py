"""Blocked (flash-style) causal attention Pallas TPU kernel.

Online-softmax attention with GQA, optional sliding window and logit
softcap — one kernel covers phi3/llama (full causal), gemma2/3 (window +
softcap) and the hybrid's shared block.  VMEM working set per grid step:
one (bq, hd) query tile, one (bk, hd) K/V tile pair and the f32
running (m, l, acc) scratch; K/V tiles stream down the innermost grid
dimension, and out-of-band blocks (beyond causal front or behind the
sliding window) are skipped via ``pl.when`` so windowed layers do
O(S·W) work, not O(S²).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, softcap: float, window: int, bq: int, bk: int,
            n_k: int):
    i = pl.program_id(2)          # query block
    j = pl.program_id(3)          # kv block (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # band check: is this (i, j) block inside the causal/window band?
    q_lo, q_hi = i * bq, i * bq + bq - 1
    k_lo, k_hi = j * bk, j * bk + bk - 1
    relevant = k_lo <= q_hi
    if window > 0:
        relevant &= k_hi > q_lo - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, 0]                     # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_scr[...][:, 0] * alpha + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when(j == n_k - 1)
    def _finalize():
        l = l_scr[...][:, 0]
        o_ref[0, 0, ...] = (acc_scr[...]
                            / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def _paged_kernel(*refs, scale: float, softcap: float,
                  bs: int, n_blk: int, quant: bool):
    """Paged-attention decode read: one query token per sequence against
    KV pages selected by the scalar-prefetched block table.

    Grid (B, H, n_blk); the innermost dimension walks the LOGICAL blocks
    of one sequence while the BlockSpec index_map streams in the
    PHYSICAL page ``block_tables[b, j]`` — the gather never
    materialises; unallocated (-1) entries are clipped to page 0 by the
    index_map and masked here.

    ``quant=True`` adds per-(page, offset, kv-head) f32 scale tiles
    streamed through the same index_map as the int8 K/V pages; the
    dequant multiply happens in VREGs right before the dot, so the f32
    pool never exists anywhere.
    """
    if quant:
        (bt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
         o_ref, m_scr, l_scr, acc_scr) = refs
    else:
        (bt_ref, len_ref, q_ref, k_ref, v_ref,
         o_ref, m_scr, l_scr, acc_scr) = refs
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(bt_ref[b, j] >= 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (1, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)        # (bs, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)       # (1, bs)
        t = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        mask = t < len_ref[b]
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, 0]                     # (1,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_scr[...][:, 0] * alpha + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when(j == n_blk - 1)
    def _finalize():
        l = l_scr[...][:, 0]
        o_ref[0, ...] = (acc_scr[...]
                         / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale: float, softcap: float = 0.0,
                    k_scale=None, v_scale=None,
                    interpret: bool = False):
    """Paged single-token decode attention (GQA).

    q: (B, H, hd); k_pages/v_pages: (num_blocks, bs, K, hd) shared page
    pool; block_tables: (B, n_blk) int32 physical page per logical block
    (-1 = unallocated); lengths: (B,) valid context per row.  The block
    table and lengths ride the scalar-prefetch channel so the page
    lookup happens in the BlockSpec index_map (the vLLM-on-TPU layout).

    For an int8 pool pass ``k_scale``/``v_scale`` (num_blocks, bs, K):
    the scale tiles stream through the same page index_map and the
    dequant fuses into the attention read.  Returns (B, H, hd).
    """
    B, H, hd = q.shape
    nB, bs, Kh, _ = k_pages.shape
    n_blk = block_tables.shape[1]
    G = H // Kh
    quant = k_scale is not None
    qt = q.reshape(B, H, 1, hd)
    bt = block_tables.astype(jnp.int32)
    ln = lengths.astype(jnp.int32)

    def page_map(b, h, j, bt_r, ln_r, G=G):
        return (jnp.maximum(bt_r[b, j], 0), 0, h // G, 0)

    def scale_map(b, h, j, bt_r, ln_r, G=G):
        return (jnp.maximum(bt_r[b, j], 0), 0, h // G)

    in_specs = [
        pl.BlockSpec((1, 1, 1, hd),
                     lambda b, h, j, bt_r, ln_r: (b, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, hd), page_map),
        pl.BlockSpec((1, bs, 1, hd), page_map),
    ]
    operands = [qt, k_pages, v_pages]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, 1), scale_map),
                     pl.BlockSpec((1, bs, 1), scale_map)]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, n_blk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, hd),
                               lambda b, h, j, bt_r, ln_r: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, softcap=softcap,
                          bs=bs, n_blk=n_blk, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(bt, ln, *operands)
    return out


def _paged_extend_kernel(*refs, scale: float, softcap: float,
                         bs: int, n_blk: int, s_len: int, quant: bool):
    """Fused multi-token extend read: S queries per row walk the row's
    context pages (masked strictly below ``pos`` — the pre-write view),
    then attend the S-token suffix causally at grid step ``j == n_blk``.

    The suffix K/V arrives as a dense (B, S, K, hd) operand — on a
    quantized pool the caller passes the int8 ROUND-TRIP so the scored
    logits match what later page reads reconstruct.  Finalisation
    happens in a second ``pl.when`` at the suffix step (pl.when blocks
    run in body order).
    """
    if quant:
        (bt_ref, pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
         kn_ref, vn_ref, o_ref, m_scr, l_scr, acc_scr) = refs
    else:
        (bt_ref, pos_ref, q_ref, k_ref, v_ref,
         kn_ref, vn_ref, o_ref, m_scr, l_scr, acc_scr) = refs
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _online_update(s, mask, v):
        m_prev = m_scr[...][:, 0]                     # (S,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_scr[...][:, 0] * alpha
                      + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when((j < n_blk) & (bt_ref[b, jnp.minimum(j, n_blk - 1)] >= 0))
    def _context():
        q = q_ref[0, 0].astype(jnp.float32)           # (S, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)        # (bs, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        t = j * bs + jax.lax.broadcasted_iota(jnp.int32, (s_len, bs), 1)
        mask = t < pos_ref[b]
        s = jnp.where(mask, s, NEG_INF)
        _online_update(s, mask, v)

    @pl.when(j == n_blk)
    def _suffix():
        q = q_ref[0, 0].astype(jnp.float32)           # (S, hd)
        k = kn_ref[0, :, 0].astype(jnp.float32)       # (S, hd)
        v = vn_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qi = jax.lax.broadcasted_iota(jnp.int32, (s_len, s_len), 0)
        kj = jax.lax.broadcasted_iota(jnp.int32, (s_len, s_len), 1)
        mask = kj <= qi
        s = jnp.where(mask, s, NEG_INF)
        _online_update(s, mask, v)

    @pl.when(j == n_blk)
    def _finalize():
        l = l_scr[...][:, 0]
        o_ref[0, 0, ...] = (acc_scr[...]
                            / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "interpret"))
def paged_extend_attention(q, k_pages, v_pages, k_new, v_new,
                           block_tables, pos, *, scale: float,
                           softcap: float = 0.0,
                           k_scale=None, v_scale=None,
                           interpret: bool = False):
    """Paged multi-token extend attention (GQA) — the fused twin of the
    gather read in ``models.layers.attention_extend_paged``.

    q: (B, S, H, hd) new-token queries at absolute positions
    ``pos + i``; k_new/v_new: (B, S, K, hd) the suffix K/V they attend
    causally; k_pages/v_pages: (num_blocks, bs, K, hd) pool (context is
    the PRE-write view, masked strictly below ``pos``); block_tables:
    (B, n_blk) int32 (-1 = unallocated); pos: (B,) int32.  Optional
    ``k_scale``/``v_scale`` (num_blocks, bs, K) fuse the int8 dequant
    into the page read.  Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    nB, bs, Kh, _ = k_pages.shape
    n_blk = block_tables.shape[1]
    G = H // Kh
    quant = k_scale is not None
    qt = q.transpose(0, 2, 1, 3)                      # (B, H, S, hd)
    bt = block_tables.astype(jnp.int32)
    ps = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))

    def page_map(b, h, j, bt_r, ps_r, G=G):
        return (jnp.maximum(bt_r[b, jnp.minimum(j, n_blk - 1)], 0),
                0, h // G, 0)

    def scale_map(b, h, j, bt_r, ps_r, G=G):
        return (jnp.maximum(bt_r[b, jnp.minimum(j, n_blk - 1)], 0),
                0, h // G)

    def new_map(b, h, j, bt_r, ps_r, G=G):
        return (b, 0, h // G, 0)

    in_specs = [
        pl.BlockSpec((1, 1, S, hd),
                     lambda b, h, j, bt_r, ps_r: (b, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, hd), page_map),
        pl.BlockSpec((1, bs, 1, hd), page_map),
    ]
    operands = [qt, k_pages, v_pages]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, 1), scale_map),
                     pl.BlockSpec((1, bs, 1), scale_map)]
        operands += [k_scale, v_scale]
    in_specs += [pl.BlockSpec((1, S, 1, hd), new_map),
                 pl.BlockSpec((1, S, 1, hd), new_map)]
    operands += [k_new, v_new]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, n_blk + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, S, hd),
                               lambda b, h, j, bt_r, ps_r: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S, 1), jnp.float32),
            pltpu.VMEM((S, 1), jnp.float32),
            pltpu.VMEM((S, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_extend_kernel, scale=scale,
                          softcap=softcap, bs=bs, n_blk=n_blk, s_len=S,
                          quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        interpret=interpret,
    )(bt, ps, *operands)
    return out.transpose(0, 2, 1, 3)


def _pick_block(n: int, pref=(512, 256, 128, 64, 32, 16, 8)) -> int:
    for c in pref:
        if n % c == 0 and c <= n:
            return c
    return n


@functools.partial(jax.jit, static_argnames=(
    "scale", "window", "softcap", "interpret"))
def flash_attention(q, k, v, *, scale: float, window: int = 0,
                    softcap: float = 0.0, interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, T, K, hd) with H % K == 0 (GQA).

    Causal; ``window`` > 0 adds a sliding window.  Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    _, T, Kh, _ = k.shape
    G = H // Kh
    qt = q.transpose(0, 2, 1, 3)                  # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)                  # (B, K, T, hd)
    vt = v.transpose(0, 2, 1, 3)
    bq, bk = _pick_block(S), _pick_block(T)
    grid = (B, H, S // bq, T // bk)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, softcap=softcap,
                          window=window, bq=bq, bk=bk, n_k=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
