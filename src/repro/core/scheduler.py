"""Preemptive multi-tenant task scheduler (discrete-event).

Implements the hub scheduler of Fig. 5a: per-device queues, task
priorities, deadlines with preemption ("the upscaling of live streaming
video ... higher priority than the classification of newly acquired
gallery photos").  Policies: fifo | priority | edf.

Deterministic discrete-event simulation: the same workload always
produces the same schedule, which the QoE benchmark and the property
tests rely on.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.perf_model import Estimate, TaskCost


@dataclass
class AITask:
    uid: int
    kind: str                        # "inference" | "training" | "stream"
    duration_s: float                # execution time on assigned device
    device: str
    priority: int = 0                # higher = more urgent
    deadline: Optional[float] = None  # absolute sim time
    arrival: float = 0.0
    preemptible: bool = True
    owner: str = "user"
    # bookkeeping
    remaining_s: float = field(default=None)
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    preemptions: int = 0

    def __post_init__(self):
        if self.remaining_s is None:
            self.remaining_s = self.duration_s

    @property
    def missed_deadline(self) -> bool:
        return (self.deadline is not None and self.finish_time is not None
                and self.finish_time > self.deadline + 1e-9)


def quantile_higher(values, q: float) -> float:
    """Ceil-based sample quantile: ``sorted(values)[ceil(q*(n-1))]`` —
    identical to ``np.percentile(values, 100*q, method="higher")``.

    The previous p99 used ``int(0.99*n) - 1``, which is biased LOW for
    small samples (n=2 reported the *minimum* latency as "p99"); a tail
    quantile must round up, never down.
    """
    if not values:
        raise ValueError("quantile of empty sample")
    s = sorted(values)
    return s[min(len(s) - 1, math.ceil(q * (len(s) - 1)))]


def admission_rank(policy: str, *, priority: int = 0, arrival: float = 0.0,
                   deadline: Optional[float] = None, uid: int = 0):
    """QoE ordering key (lower sorts first) — the ONE policy definition
    shared by this discrete-event scheduler and the serving engine's
    admission queue (serving.engine), so simulated schedules and the
    real continuous-batching runtime agree on who goes next.
    """
    if policy == "fifo":
        return (arrival, uid)
    if policy == "priority":
        return (-priority, arrival, uid)
    if policy == "edf":
        dl = deadline if deadline is not None else math.inf
        return (dl, -priority, uid)
    raise ValueError(policy)


def plan_wave(policy: str, entries, budget: Optional[int] = None,
              metrics=None) -> dict:
    """Per-wave token widths for a live mixed admit/decode frontier.

    ``entries``: dicts with ``id`` (slot), ``want`` (the width the slot
    would naturally take this wave: 1 for a plain decode, up to the
    chunk width for prompt catch-up, up to gamma for a speculative
    round) plus the ``admission_rank`` QoE fields (``priority`` /
    ``arrival`` / ``deadline`` / ``uid``).

    Allocation under ``budget`` (total tokens this wave may score):
    every entry is granted width 1 first — an admitted slot always
    advances, so a saturated wave degrades to plain continuous batching
    instead of starving anyone — then the remaining budget is granted
    best-rank-first up to each entry's ``want``.  ``budget=None``
    disables the cap (every slot takes its natural width).  Returns
    ``{id: width}``.

    ``metrics``: optional ``serving.telemetry.MetricsRegistry`` —
    budgeted plans record the wave's budget utilization (granted /
    budget, ``sched.budget_utilization`` histogram) and count demoted
    slots (granted < wanted, ``sched.demotions``) so QoE pressure is
    visible without sampling ``engine.last_plan``.

    Width is deliberately the only lever: shrinking a catch-up or
    speculative span never changes the tokens a request emits (chunked
    teacher-forcing and speculative acceptance are both
    schedule-invariant), so QoE shaping here cannot cause token drift.
    """
    if budget is None:
        return {e["id"]: max(1, int(e["want"])) for e in entries}
    order = sorted(entries, key=lambda e: admission_rank(
        policy, priority=e.get("priority", 0),
        arrival=e.get("arrival", 0.0), deadline=e.get("deadline"),
        uid=e.get("uid", 0)))
    widths = {e["id"]: 1 for e in order}
    left = max(0, int(budget) - len(order))
    for e in order:
        if left <= 0:
            break
        extra = min(max(1, int(e["want"])) - 1, left)
        widths[e["id"]] += extra
        left -= extra
    if metrics is not None and entries:
        metrics.histogram("sched.budget_utilization",
                          (0.25, 0.5, 0.75, 0.9, 1.0)).observe(
            sum(widths.values()) / max(int(budget), 1))
        demoted = sum(1 for e in entries
                      if widths[e["id"]] < max(1, int(e["want"])))
        if demoted:
            metrics.counter("sched.demotions").inc(demoted)
    return widths


def _rank(policy: str, task: AITask, now: float):
    del now  # rank is currently time-invariant; kept for call-site compat
    return admission_rank(policy, priority=task.priority,
                          arrival=task.arrival, deadline=task.deadline,
                          uid=task.uid)


@dataclass
class _DeviceState:
    running: Optional[AITask] = None
    run_started: float = 0.0
    queue: list = field(default_factory=list)  # heap of (rank, uid, task)


class EdgeScheduler:
    """Event-driven preemptive scheduler across registered devices."""

    def __init__(self, policy: str = "priority"):
        self.policy = policy
        self._dev: dict[str, _DeviceState] = {}
        self._events: list = []      # (time, seq, kind, payload)
        self._seq = itertools.count()
        self.now = 0.0
        self.completed: list[AITask] = []
        self.trace: list[tuple] = []  # (time, event, task_uid, device)

    # ------------------------------------------------------------------
    def submit(self, task: AITask) -> None:
        heapq.heappush(self._events,
                       (task.arrival, next(self._seq), "arrive", task))

    def _dstate(self, device: str) -> _DeviceState:
        return self._dev.setdefault(device, _DeviceState())

    def _start(self, device: str, task: AITask) -> None:
        ds = self._dstate(device)
        ds.running = task
        ds.run_started = self.now
        if task.start_time is None:
            task.start_time = self.now
        heapq.heappush(self._events,
                       (self.now + task.remaining_s, next(self._seq),
                        "finish", (device, task)))
        self.trace.append((self.now, "start", task.uid, device))

    def _enqueue(self, device: str, task: AITask) -> None:
        ds = self._dstate(device)
        heapq.heappush(ds.queue,
                       (_rank(self.policy, task, self.now), task.uid, task))

    def _maybe_preempt(self, device: str, incoming: AITask) -> bool:
        ds = self._dstate(device)
        cur = ds.running
        if cur is None or not cur.preemptible or self.policy == "fifo":
            return False
        if _rank(self.policy, incoming, self.now) >= \
                _rank(self.policy, cur, self.now):
            return False
        # stop the running task, bank its progress, requeue it
        done = self.now - ds.run_started
        cur.remaining_s = max(0.0, cur.remaining_s - done)
        cur.preemptions += 1
        ds.running = None
        self.trace.append((self.now, "preempt", cur.uid, device))
        self._enqueue(device, cur)
        self._start(device, incoming)
        return True

    def _dispatch(self, device: str) -> None:
        ds = self._dstate(device)
        if ds.running is None and ds.queue:
            _, _, task = heapq.heappop(ds.queue)
            self._start(device, task)

    # ------------------------------------------------------------------
    def run(self, until: float = math.inf) -> list[AITask]:
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            if t > until:
                break
            self.now = t
            if kind == "arrive":
                task: AITask = payload
                if not self._maybe_preempt(task.device, task):
                    self._enqueue(task.device, task)
                    self._dispatch(task.device)
            elif kind == "finish":
                device, task = payload
                ds = self._dstate(device)
                if ds.running is not task:
                    continue  # stale finish event (task was preempted)
                elapsed = self.now - ds.run_started
                if elapsed + 1e-12 < task.remaining_s:
                    continue  # stale (preempted + restarted)
                task.remaining_s = 0.0
                task.finish_time = self.now
                ds.running = None
                self.completed.append(task)
                self.trace.append((self.now, "finish", task.uid, device))
                self._dispatch(device)
        return self.completed

    # -- metrics ----------------------------------------------------------
    def qoe_report(self) -> dict:
        done = self.completed
        if not done:
            return {"completed": 0}
        waits = [t.start_time - t.arrival for t in done]
        lats = [t.finish_time - t.arrival for t in done]
        misses = [t for t in done if t.missed_deadline]
        return {
            "completed": len(done),
            "mean_wait_s": sum(waits) / len(done),
            "p99_latency_s": quantile_higher(lats, 0.99),
            "mean_latency_s": sum(lats) / len(done),
            "deadline_misses": len(misses),
            "miss_rate": len(misses) / len(done),
            "preemptions": sum(t.preemptions for t in done),
        }
