"""Early-exit networks (paper Sustainability pillar; HAPI [25]/SPINN [24]).

Attach lightweight exit heads to intermediate layers of a dense trunk;
at serve time a confidence threshold preempts computation on easy
inputs.  TPU adaptation (DESIGN.md): exits are evaluated on the whole
batch SPMD-style and the *batch exit mask* decides skipping — per-sample
divergent control flow has no TPU analogue, so savings are realized at
batch granularity (all-exited => remaining layers skipped) and measured
in expected-FLOPs for per-sample accounting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T


def init_exit_heads(cfg: ModelConfig, key, exit_layers: Sequence[int]):
    """One (norm + unembed-tied) head per exit point."""
    norm_init, _ = L.make_norm(cfg)
    heads = []
    for i, _ in enumerate(exit_layers):
        heads.append({"ln": norm_init(cfg.d_model)})
    return {"exits": heads, "exit_layers": tuple(exit_layers)}


def _layer(trunk, i: int):
    return jax.tree.map(lambda a: a[i], trunk["layers"])


def _exit_logits(cfg, params, head, x):
    _, norm = L.make_norm(cfg)
    h = norm(head["ln"], x)
    return L.unembed(cfg, params["embed"], params["unembed"], h)


def forward_with_exits(cfg: ModelConfig, params, heads, tokens):
    """All exit logits (training mode). Returns list[(layer, logits)]."""
    if cfg.pattern_period > 1:
        raise NotImplementedError("early exits target uniform dense stacks")
    x = L.embed(cfg, params["embed"], tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    exit_at = dict(zip(heads["exit_layers"], range(len(heads["exits"]))))
    outs = []
    for i in range(cfg.num_layers):
        x = T.block_fwd(cfg, _layer(params["trunk"], i), x, positions,
                        is_global=True)
        if i in exit_at:
            outs.append((i, _exit_logits(cfg, params,
                                         heads["exits"][exit_at[i]], x)))
    _, norm = L.make_norm(cfg)
    xf = norm(params["final_norm"], x)
    outs.append((cfg.num_layers - 1,
                 L.unembed(cfg, params["embed"], params["unembed"], xf)))
    return outs


def exit_loss(cfg: ModelConfig, params, heads, batch,
              weights: Optional[Sequence[float]] = None):
    """Weighted sum of per-exit cross-entropies (joint training)."""
    outs = forward_with_exits(cfg, params, heads, batch["tokens"])
    targets = batch["targets"]
    if weights is None:
        weights = [1.0] * len(outs)
    total = 0.0
    for w, (_, logits) in zip(weights, outs):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        total = total + w * jnp.mean(nll)
    return total / sum(weights)


@dataclass
class ExitReport:
    predictions: jnp.ndarray       # (B, S)
    exit_layer: jnp.ndarray        # (B,) layer index each example left at
    expected_layers: float         # mean layers executed per example
    flops_saved_frac: float        # vs. always running the full stack


def serve_early_exit(cfg: ModelConfig, params, heads, tokens,
                     threshold: float = 0.7,
                     conf_reduce: str = "mean") -> ExitReport:
    """Confidence-gated inference.

    conf_reduce: per-example confidence over token positions — "mean"
    (LM-style; first tokens of a sequence are inherently unpredictable)
    or "min" (strictest, classification-style).
    """
    x = L.embed(cfg, params["embed"], tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    exit_at = dict(zip(heads["exit_layers"], range(len(heads["exits"]))))

    exited = jnp.zeros((B,), bool)
    exit_layer = jnp.full((B,), cfg.num_layers - 1, jnp.int32)
    preds = jnp.zeros((B, S), jnp.int32)

    for i in range(cfg.num_layers):
        if bool(jnp.all(exited)):
            break  # batch-granular compute skip (TPU-friendly)
        x = T.block_fwd(cfg, _layer(params["trunk"], i), x, positions,
                        is_global=True)
        if i in exit_at:
            logits = _exit_logits(cfg, params,
                                  heads["exits"][exit_at[i]], x)
            probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
            tok_conf = jnp.max(probs, axis=-1)
            conf = (jnp.min(tok_conf, axis=-1) if conf_reduce == "min"
                    else jnp.mean(tok_conf, axis=-1))
            newly = (~exited) & (conf >= threshold)
            preds = jnp.where(newly[:, None], jnp.argmax(logits, -1), preds)
            exit_layer = jnp.where(newly, i, exit_layer)
            exited = exited | newly

    _, norm = L.make_norm(cfg)
    logits = L.unembed(cfg, params["embed"], params["unembed"],
                       norm(params["final_norm"], x))
    preds = jnp.where(exited[:, None], preds, jnp.argmax(logits, -1))

    expected = float(jnp.mean(exit_layer + 1))
    saved = 1.0 - expected / cfg.num_layers
    return ExitReport(preds, exit_layer, expected, saved)
