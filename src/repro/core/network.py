"""Multi-channel consumer-edge network model + load balancer.

The paper's networking pillar: the hub speaks many protocols at once
(Wi-Fi / BLE / Zigbee / UWB / 5G), load-balances transfers across
channels and slices bandwidth per-tenant for QoE.  Deterministic
analytical model — the discrete-event scheduler prices every transfer
through this.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass(frozen=True)
class Channel:
    name: str
    bandwidth_bps: float     # usable application-layer throughput (bits/s)
    latency_s: float         # one-way propagation + stack latency
    loss_rate: float = 0.0   # retransmission overhead fraction
    energy_per_bit: float = 10e-9  # J/bit on the device side


CHANNEL_CATALOGUE = {
    "wifi6": Channel("wifi6", 600e6, 2e-3, 0.01, 5e-9),
    "wifi-legacy": Channel("wifi-legacy", 50e6, 5e-3, 0.03, 8e-9),
    "ble": Channel("ble", 1.4e6, 15e-3, 0.02, 2e-9),
    "zigbee": Channel("zigbee", 0.2e6, 20e-3, 0.02, 1.5e-9),
    "uwb": Channel("uwb", 27e6, 1e-3, 0.01, 4e-9),
    "5g-local": Channel("5g-local", 200e6, 8e-3, 0.01, 12e-9),
    "ethernet": Channel("ethernet", 1e9, 0.5e-3, 0.0, 3e-9),
}


@dataclass
class Transfer:
    bytes: float
    latency_s: float
    energy_j: float
    channels: tuple


def transfer_time(nbytes: float, ch: Channel) -> float:
    eff = ch.bandwidth_bps * (1.0 - ch.loss_rate)
    return ch.latency_s + nbytes * 8.0 / eff


class MultiChannelLink:
    """A device<->hub link over several physical channels.

    ``send`` stripes a payload across channels proportionally to their
    effective bandwidth (water-filling load balance); ``reserve`` slices
    off guaranteed bandwidth for a tenant (QoE isolation).
    """

    def __init__(self, channels: Sequence[Channel]):
        if not channels:
            raise ValueError("link needs at least one channel")
        self.channels = list(channels)
        self._reserved: dict[str, float] = {}  # tenant -> fraction

    @property
    def free_fraction(self) -> float:
        return max(0.0, 1.0 - sum(self._reserved.values()))

    def reserve(self, tenant: str, fraction: float) -> bool:
        if fraction <= 0 or fraction > self.free_fraction + 1e-12:
            return False
        self._reserved[tenant] = fraction
        return True

    def release(self, tenant: str) -> None:
        self._reserved.pop(tenant, None)

    def send(self, nbytes: float, *, tenant: Optional[str] = None) -> Transfer:
        """Stripe nbytes across channels; returns the completion time of
        the slowest stripe (all channels start together)."""
        frac = self._reserved.get(tenant, self.free_fraction if tenant is None
                                  else self.free_fraction)
        effs = [c.bandwidth_bps * (1 - c.loss_rate) * frac
                for c in self.channels]
        total = sum(effs)
        lat = 0.0
        energy = 0.0
        for c, eff in zip(self.channels, effs):
            share = nbytes * (eff / total)
            t = c.latency_s + share * 8.0 / max(eff, 1.0)
            lat = max(lat, t)
            energy += share * 8.0 * c.energy_per_bit
        return Transfer(nbytes, lat, energy,
                        tuple(c.name for c in self.channels))

    def best_single_channel(self, nbytes: float) -> tuple[Channel, float]:
        """Latency-optimal single channel for a payload (small payloads
        prefer low-latency channels, large ones high-bandwidth)."""
        best = min(self.channels, key=lambda c: transfer_time(nbytes, c))
        return best, transfer_time(nbytes, best)
