"""The Orchestrator (paper Fig. 5a): resource manager + scheduler +
performance controller + task controllers, composed.

Responsibilities implemented here:
  * task placement — trust-zone filter, then latency-optimal device from
    the performance controller (analytical roofline + historical EWMA),
    network transfer priced through each device's multi-channel link;
  * QoE scheduling — priorities/deadlines/preemption via EdgeScheduler;
  * fault tolerance — tasks on a failed device are transparently
    re-placed and re-executed;
  * split offloading — inference can be cut between device and hub
    (core.split) when that beats either endpoint alone.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core import split as split_mod
from repro.core import trustzones as tz
from repro.core.perf_model import (
    DeviceSpec,
    HistoricalEstimator,
    TaskCost,
    estimate,
    inference_cost,
    training_cost,
)
from repro.core.resource import DeviceHandle, DeviceRegistry
from repro.core.scheduler import AITask, EdgeScheduler


@dataclass
class TaskSpec:
    """What a user/app asks for (hardware-independent)."""
    kind: str                          # "inference" | "training" | "stream"
    model: ModelConfig
    batch: int = 1
    seq: int = 128
    priority: int = 0
    deadline_rel: Optional[float] = None   # seconds after arrival
    arrival: float = 0.0
    data: Optional[tz.DataItem] = None
    source_device: Optional[str] = None    # where the input lives
    allow_split: bool = False
    weight_bits: int = 16


@dataclass
class Placement:
    device: str
    latency_s: float
    energy_j: float
    transfer_s: float
    split: Optional[split_mod.SplitDecision] = None


class Orchestrator:
    def __init__(self, registry: DeviceRegistry, hub_device: str,
                 policy: str = "priority",
                 zone_policy: Optional[tz.ZonePolicy] = None):
        self.registry = registry
        self.hub_device = hub_device
        self.scheduler = EdgeScheduler(policy=policy)
        self.history = HistoricalEstimator()
        self.zone_policy = zone_policy or tz.ZonePolicy()
        self._uids = itertools.count()
        self._task_meta: dict[int, tuple[TaskSpec, Placement]] = {}

    # ------------------------------------------------------------------
    def _candidates(self, spec: TaskSpec) -> list[str]:
        train = True if spec.kind == "training" else None
        names = self.registry.available(train_capable=train)
        if spec.data is not None:
            allowed = []
            for n in names:
                h = self.registry.get(n)
                if tz.allowed(spec.data, n, h.zone, h.owner,
                              self.zone_policy):
                    allowed.append(n)
            names = allowed
        return names

    def _cost(self, spec: TaskSpec) -> TaskCost:
        if spec.kind == "training":
            return training_cost(spec.model, spec.batch, spec.seq)
        return inference_cost(spec.model, spec.batch, spec.seq,
                              weight_bits=spec.weight_bits)

    def place(self, spec: TaskSpec) -> Placement:
        """Performance-controller placement: min-latency feasible device."""
        cost = self._cost(spec)
        best: Optional[Placement] = None
        for name in self._candidates(spec):
            h = self.registry.get(name)
            hist = self.history.predict(self._task_kind(spec), name)
            est = estimate(cost, h.spec)
            if not est.fits_memory:
                continue
            compute_s = hist if hist is not None else est.latency_s
            # queueing delay proxy: deeper queues wait longer
            compute_s *= (1.0 + 0.25 * h.queue_depth)
            transfer_s = 0.0
            if spec.source_device and spec.source_device != name:
                transfer_s = h.link.send(cost.transfer_bytes).latency_s
            total = compute_s + transfer_s
            cand = Placement(name, total, est.energy_j, transfer_s)
            if best is None or cand.latency_s < best.latency_s:
                best = cand

        # consider split execution device<->hub for inference
        if (spec.allow_split and spec.source_device
                and spec.kind == "inference"
                and spec.model.pattern_period <= 1
                and spec.source_device in self.registry
                and self.hub_device in self.registry):
            dev = self.registry.get(spec.source_device)
            hub = self.registry.get(self.hub_device)
            dec = split_mod.choose_split(spec.model, dev.spec, hub.spec,
                                         dev.link, spec.batch, spec.seq)
            if best is None or dec.total_s < best.latency_s:
                best = Placement(self.hub_device, dec.total_s, 0.0,
                                 dec.transfer_s, split=dec)
        if best is None:
            raise RuntimeError(
                f"no feasible device for task {spec.kind} "
                f"(zone={getattr(spec.data, 'zone', None)})")
        return best

    @staticmethod
    def _task_kind(spec: TaskSpec) -> str:
        return f"{spec.kind}:{spec.model.name}:{spec.batch}x{spec.seq}"

    # ------------------------------------------------------------------
    def submit(self, spec: TaskSpec) -> int:
        placement = self.place(spec)
        uid = next(self._uids)
        deadline = (spec.arrival + spec.deadline_rel
                    if spec.deadline_rel is not None else None)
        task = AITask(uid=uid, kind=spec.kind,
                      duration_s=placement.latency_s,
                      device=placement.device, priority=spec.priority,
                      deadline=deadline, arrival=spec.arrival)
        self.registry.get(placement.device).queue_depth += 1
        self.scheduler.submit(task)
        self._task_meta[uid] = (spec, placement)
        return uid

    def run(self, until: float = math.inf) -> dict:
        done = self.scheduler.run(until)
        for t in done:
            spec, placement = self._task_meta[t.uid]
            self.registry.get(placement.device).queue_depth = max(
                0, self.registry.get(placement.device).queue_depth - 1)
            self.history.observe(self._task_kind(spec), placement.device,
                                 t.finish_time - t.start_time)
        return self.scheduler.qoe_report()

    # -- fault tolerance --------------------------------------------------
    def fail_device(self, name: str) -> list[int]:
        """Device dropped out: re-place its unfinished tasks elsewhere.

        Returns the uids of re-placed tasks.
        """
        self.registry.get(name).available = False
        moved = []
        finished = {t.uid for t in self.scheduler.completed}
        for uid, (spec, placement) in list(self._task_meta.items()):
            if placement.device != name or uid in finished:
                continue
            respec = TaskSpec(**{**spec.__dict__,
                                 "arrival": self.scheduler.now})
            new_uid = self.submit(respec)
            moved.append(new_uid)
        return moved
