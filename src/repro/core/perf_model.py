"""Performance controller: analytical roofline estimators per device.

The orchestrator's *performance controller* (paper Fig. 5a) assesses an
AI-task's runtime/energy on a candidate device "through analytical or
historical estimators".  We implement both:

* analytical — three-term roofline (compute / memory / link) from the
  task's FLOPs & bytes and the device's peak numbers;
* historical — an EWMA over observed runtimes, keyed by (task, device).

Device catalogue spans the consumer-edge tiers the paper describes, from
sensor-class MCUs to the EdgeAI-Hub itself (TPU-v5e-class numbers: the
target substrate of this reproduction, DESIGN.md §Hardware adaptation).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional

from repro.configs.base import InputShape, ModelConfig

# TPU v5e hardware constants — also used by launch/roofline.py
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # B/s per chip
ICI_BW = 50e9                   # B/s per link


@dataclass(frozen=True)
class DeviceSpec:
    name: str
    kind: str                   # hub | phone | tv | wearable | sensor | robot
    peak_flops: float           # FLOP/s (dense, best precision)
    mem_bw: float               # B/s
    memory_bytes: float
    idle_power: float           # W
    peak_power: float           # W
    train_capable: bool = False
    # DVFS: available frequency scaling states (fraction of peak)
    dvfs_states: tuple = (1.0,)

    def scaled(self, dvfs: float) -> "DeviceSpec":
        return replace(self, peak_flops=self.peak_flops * dvfs,
                       peak_power=self.peak_power * dvfs ** 2)


# Representative consumer-edge device catalogue (order-of-magnitude
# figures from public spec sheets; the EdgeAI-Hub is v5e-class).
DEVICE_CATALOGUE = {
    "edgeai-hub": DeviceSpec("edgeai-hub", "hub", PEAK_FLOPS_BF16, HBM_BW,
                             16e9, 30.0, 250.0, train_capable=True,
                             dvfs_states=(0.5, 0.75, 1.0)),
    "flagship-phone": DeviceSpec("flagship-phone", "phone", 30e12, 60e9,
                                 12e9, 0.5, 8.0,
                                 dvfs_states=(0.25, 0.5, 1.0)),
    "mid-phone": DeviceSpec("mid-phone", "phone", 6e12, 30e9, 6e9, 0.3, 5.0),
    "smart-tv": DeviceSpec("smart-tv", "tv", 8e12, 40e9, 4e9, 15.0, 60.0),
    "wearable": DeviceSpec("wearable", "wearable", 0.5e12, 8e9, 1e9,
                           0.05, 1.0),
    "iot-sensor": DeviceSpec("iot-sensor", "sensor", 0.01e12, 1e9, 0.25e9,
                             0.01, 0.3),
    "robot-vacuum": DeviceSpec("robot-vacuum", "robot", 2e12, 20e9, 2e9,
                               2.0, 15.0),
    "old-phone": DeviceSpec("old-phone", "phone", 1e12, 15e9, 3e9, 0.3, 4.0),
}


@dataclass(frozen=True)
class TaskCost:
    """Hardware-independent cost of one execution of an AI-task."""
    flops: float
    weight_bytes: float
    activation_bytes: float
    transfer_bytes: float = 0.0     # input/output payload over the network

    @property
    def mem_bytes(self) -> float:
        return self.weight_bytes + self.activation_bytes


def model_flops_per_token(cfg: ModelConfig) -> float:
    """Decode FLOPs/token ~= 2 * active params (weight reuse ignored)."""
    return 2.0 * cfg.active_param_count()


def train_flops(cfg: ModelConfig, tokens: int) -> float:
    """6ND rule (fwd 2ND + bwd 4ND) on active params."""
    return 6.0 * cfg.active_param_count() * tokens


def inference_cost(cfg: ModelConfig, batch: int, seq: int,
                   weight_bits: int = 16) -> TaskCost:
    n_tok = batch * seq
    return TaskCost(
        flops=2.0 * cfg.active_param_count() * n_tok,
        weight_bytes=cfg.param_count() * weight_bits / 8,
        activation_bytes=2.0 * n_tok * cfg.d_model * 12,  # ~12 live tensors
        transfer_bytes=4.0 * n_tok,
    )


def training_cost(cfg: ModelConfig, batch: int, seq: int) -> TaskCost:
    n_tok = batch * seq
    return TaskCost(
        flops=6.0 * cfg.active_param_count() * n_tok,
        weight_bytes=cfg.param_count() * 16,  # w + grad + adam m,v (f32)
        activation_bytes=2.0 * n_tok * cfg.d_model * cfg.num_layers,
        transfer_bytes=cfg.param_count() * 2,  # update shipping (FL)
    )


@dataclass
class Estimate:
    compute_s: float
    memory_s: float
    latency_s: float
    energy_j: float
    fits_memory: bool

    @property
    def bottleneck(self) -> str:
        return "compute" if self.compute_s >= self.memory_s else "memory"


def estimate(task: TaskCost, dev: DeviceSpec, *, dvfs: float = 1.0,
             utilization: float = 0.4) -> Estimate:
    """Roofline latency + energy on one device (no network)."""
    d = dev.scaled(dvfs) if dvfs != 1.0 else dev
    compute_s = task.flops / (d.peak_flops * utilization)
    memory_s = task.mem_bytes / d.mem_bw
    latency = max(compute_s, memory_s)
    energy = latency * d.peak_power * 0.7 + latency * d.idle_power
    return Estimate(compute_s, memory_s, latency, energy,
                    fits_memory=task.mem_bytes <= d.memory_bytes)


class HistoricalEstimator:
    """EWMA of observed runtimes, keyed by (task_kind, device)."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self._table: dict[tuple, float] = {}

    def observe(self, task_kind: str, device: str, latency_s: float) -> None:
        key = (task_kind, device)
        prev = self._table.get(key)
        self._table[key] = (latency_s if prev is None
                            else (1 - self.alpha) * prev
                            + self.alpha * latency_s)

    def predict(self, task_kind: str, device: str) -> Optional[float]:
        return self._table.get((task_kind, device))
