from repro.core import (
    context,
    earlyexit,
    network,
    orchestrator,
    perf_model,
    placement,
    resource,
    scheduler,
    split,
    trustzones,
)
from repro.core.hub import EdgeAIHub, default_home
from repro.core.orchestrator import Orchestrator, TaskSpec
from repro.core.scheduler import AITask, EdgeScheduler

__all__ = [
    "AITask", "EdgeAIHub", "EdgeScheduler", "Orchestrator", "TaskSpec",
    "context", "default_home", "earlyexit", "network", "orchestrator",
    "perf_model", "placement", "resource", "scheduler", "split",
    "trustzones",
]
