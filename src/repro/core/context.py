"""Shared context between sensing devices (paper §Shared context).

* ``SharedContextSpace`` — implicit context sharing: each sensor embeds
  its observations into a COMMON subspace via a per-device projection;
  downstream tasks consume fused embeddings ("embedding subsets of
  available sensors into a common subspace").
* multi-view fusion — several devices observing the same event fuse
  their embeddings to improve a shared task (smart speaker + camera).
* multi-task heads — different tasks share one DNN backend instead of
  replicating it per device.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_context_space(key, sensor_dims: Dict[str, int], shared_dim: int,
                       num_classes: int, hidden: int = 64):
    ks = jax.random.split(key, len(sensor_dims) + 2)
    proj = {
        name: L._dense_init(k, (dim, shared_dim))
        for (name, dim), k in zip(sorted(sensor_dims.items()), ks)
    }
    return {
        "proj": proj,
        "trunk_w": L._dense_init(ks[-2], (shared_dim, hidden)),
        "heads": {},
        "_key": ks[-1],
        "shared_dim": shared_dim,
        "hidden": hidden,
    }


def add_task_head(params, task: str, num_classes: int):
    key = params["_key"]
    params["_key"], sub = jax.random.split(key)
    params["heads"][task] = L._dense_init(
        sub, (params["hidden"], num_classes))
    return params


def embed_views(params, views: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Project each sensor's features into the shared subspace and fuse
    (mean over available views — robust to partial availability)."""
    embs = [views[name] @ params["proj"][name]
            for name in sorted(views) if name in params["proj"]]
    if not embs:
        raise ValueError("no recognised sensor views")
    return jnp.mean(jnp.stack(embs), axis=0)


def task_logits(params, task: str, fused: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.relu(fused @ params["trunk_w"])
    return h @ params["heads"][task]


def multiview_logits(params, task: str,
                     views: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return task_logits(params, task, embed_views(params, views))


def context_loss(params, task: str, views: Dict[str, jnp.ndarray],
                 labels: jnp.ndarray) -> jnp.ndarray:
    logits = multiview_logits(params, task, views)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
