"""Split computing (SPINN-style [24]): partition a DNN between a weak
device and the EdgeAI-Hub, shipping QUANTIZED activations at the cut.

Two halves run as real JAX programs on sliced layer stacks; the wire
payload is int8/int4-quantized activations priced through the
multi-channel network model.  ``choose_split`` is the orchestrator-side
optimizer: argmin over cut points of device-time + transfer + hub-time.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.network import MultiChannelLink
from repro.core.perf_model import DeviceSpec, TaskCost, estimate
from repro.models import layers as L
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# activation quantization for the wire
# ---------------------------------------------------------------------------

def quantize_activations(x: jnp.ndarray, bits: int = 8):
    """Per-token symmetric quantization. Returns (q, scale)."""
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax + 1e-12
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale


def dequantize_activations(q: jnp.ndarray, scale: jnp.ndarray,
                           dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def wire_bytes(x_shape: tuple, bits: int) -> float:
    n = math.prod(x_shape)
    scales = n / x_shape[-1] * 4  # f32 scale per token
    return n * bits / 8 + scales


# ---------------------------------------------------------------------------
# split execution (dense trunks; cut at layer granularity)
# ---------------------------------------------------------------------------

def _slice_layers(trunk, lo: int, hi: int):
    return jax.tree.map(lambda a: a[lo:hi], trunk)


def head_forward(cfg: ModelConfig, params, tokens, split: int):
    """Device-side: embed + layers [0, split). Returns activations."""
    if cfg.pattern_period > 1:
        raise NotImplementedError(
            "split computing cuts uniform stacks; pattern archs cut at "
            "super-block granularity via split=k*period (not needed here)")
    x = L.embed(cfg, params["embed"], tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    head = {"layers": _slice_layers(params["trunk"]["layers"], 0, split)}
    if split > 0:
        x = T.trunk_fwd(cfg.replace(num_layers=split), head, x, positions)
    return x


def tail_forward(cfg: ModelConfig, params, x, split: int):
    """Hub-side: layers [split, L) + norm + unembed."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    n = cfg.num_layers
    tail = {"layers": _slice_layers(params["trunk"]["layers"], split, n)}
    if split < n:
        x = T.trunk_fwd(cfg.replace(num_layers=n - split), tail, x, positions)
    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    return L.unembed(cfg, params["embed"], params["unembed"], x)


def split_forward(cfg: ModelConfig, params, tokens, split: int,
                  *, bits: int = 8):
    """End-to-end split inference with a quantized wire transfer.

    Returns (logits, payload_bytes).  split=0 => full offload,
    split=num_layers => fully on-device (no transfer of activations,
    but logits still come back).
    """
    x = head_forward(cfg, params, tokens, split)
    if 0 < split < cfg.num_layers:
        q, s = quantize_activations(x.astype(jnp.float32), bits)
        payload = wire_bytes(x.shape, bits)
        x = dequantize_activations(q, s, cfg.activation_dtype)
    else:
        payload = 0.0
    logits = tail_forward(cfg, params, x, split)
    return logits, payload


# ---------------------------------------------------------------------------
# orchestrator-side split optimizer
# ---------------------------------------------------------------------------

@dataclass
class SplitDecision:
    split: int
    device_s: float
    transfer_s: float
    hub_s: float
    total_s: float
    payload_bytes: float


def _per_layer_flops(cfg: ModelConfig, n_tokens: int) -> float:
    d = cfg.d_model
    attn = 2 * n_tokens * (d * cfg.num_heads * cfg.head_dim * 2
                           + d * cfg.num_kv_heads * cfg.head_dim * 2)
    mlp = 2 * n_tokens * 3 * d * cfg.d_ff
    return attn + mlp


def choose_split(cfg: ModelConfig, device: DeviceSpec, hub: DeviceSpec,
                 link: MultiChannelLink, batch: int, seq: int,
                 *, bits: int = 8, head_bits: int = 8) -> SplitDecision:
    """Latency-optimal cut point for one inference batch."""
    n_tok = batch * seq
    lflops = _per_layer_flops(cfg, n_tok)
    lbytes_dev = _per_layer_weight_bytes(cfg, head_bits)
    act_bytes = wire_bytes((batch, seq, cfg.d_model), bits)
    emb_flops = 2.0 * n_tok * cfg.d_model   # lookup-ish, negligible
    unemb_flops = 2.0 * n_tok * cfg.d_model * cfg.vocab_size

    best: Optional[SplitDecision] = None
    for k in range(cfg.num_layers + 1):
        dev_cost = TaskCost(flops=emb_flops + k * lflops,
                            weight_bytes=k * lbytes_dev,
                            activation_bytes=n_tok * cfg.d_model * 2)
        hub_cost = TaskCost(
            flops=(cfg.num_layers - k) * lflops + unemb_flops,
            weight_bytes=(cfg.num_layers - k)
            * _per_layer_weight_bytes(cfg, 16) + cfg.vocab_size * cfg.d_model * 2,
            activation_bytes=n_tok * cfg.d_model * 2)
        dev_t = estimate(dev_cost, device).latency_s
        hub_t = estimate(hub_cost, hub).latency_s if k < cfg.num_layers \
            else 0.0
        if 0 < k < cfg.num_layers:
            tr = link.send(act_bytes).latency_s
            payload = act_bytes
        elif k == 0:
            tr = link.send(n_tok * 4).latency_s        # raw tokens up
            payload = n_tok * 4
        else:
            tr = link.send(batch * 8).latency_s        # predictions back
            payload = batch * 8
        total = dev_t + tr + hub_t
        cand = SplitDecision(k, dev_t, tr, hub_t, total, payload)
        if best is None or cand.total_s < best.total_s:
            best = cand
    return best


def _per_layer_weight_bytes(cfg: ModelConfig, bits: int) -> float:
    d = cfg.d_model
    attn = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim \
        + cfg.num_heads * cfg.head_dim * d
    return (attn + 3 * d * cfg.d_ff) * bits / 8
