"""Static resource partitioning as a generalized knapsack (paper Fig. 3).

Decides, under a monetary/area/power budget, which compute units to
place on which devices ("a training-ready NPU could be integrated to a
home hub" vs. thin clients).  Items are (device, accelerator-option)
pairs; value is the utility of the AI-tasks that placement unlocks;
weight is its cost.  Exact DP solver for integer-cost instances plus a
greedy fallback — both deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence


@dataclass(frozen=True)
class PlacementOption:
    device: str
    accelerator: str          # e.g. "npu-train", "npu-infer", "none"
    cost: int                 # integer budget units (e.g. $)
    utility: float            # aggregate task utility unlocked
    flops: float = 0.0
    train_capable: bool = False


def solve_knapsack(options: Sequence[PlacementOption], budget: int,
                   *, exclusive_per_device: bool = True
                   ) -> tuple[list[PlacementOption], float]:
    """Pick at most one option per device, maximizing utility <= budget.

    Multiple-choice knapsack via DP over (device_group, budget).
    """
    groups: dict[str, list[PlacementOption]] = {}
    for o in options:
        groups.setdefault(o.device, []).append(o)
    if not exclusive_per_device:
        groups = {f"{o.device}#{i}": [o]
                  for i, o in enumerate(options)}

    names = sorted(groups)
    # dp[b] = (utility, chosen tuple)
    dp: list[tuple[float, tuple]] = [(0.0, ())] * (budget + 1)
    for name in names:
        new_dp = list(dp)
        for o in groups[name]:
            if o.cost > budget:
                continue
            for b in range(o.cost, budget + 1):
                cand = dp[b - o.cost]
                val = cand[0] + o.utility
                if val > new_dp[b][0]:
                    new_dp[b] = (val, cand[1] + (o,))
        dp = new_dp
    best = max(dp, key=lambda x: x[0])
    return list(best[1]), best[0]


def greedy_partition(options: Sequence[PlacementOption], budget: int
                     ) -> tuple[list[PlacementOption], float]:
    """Utility-per-cost greedy (fast path for large instances)."""
    chosen: list[PlacementOption] = []
    used_devices: set[str] = set()
    total_u = 0.0
    spend = 0
    for o in sorted(options, key=lambda o: -o.utility / max(o.cost, 1)):
        if o.device in used_devices or spend + o.cost > budget:
            continue
        chosen.append(o)
        used_devices.add(o.device)
        spend += o.cost
        total_u += o.utility
    return chosen, total_u
