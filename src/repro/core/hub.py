"""EdgeAIHub facade: one object wiring the paper's whole stack together.

registry (resource manager) + orchestrator (scheduler/controllers) +
serving engine(s) + federated coordinator + shared-context space.
Examples and integration tests drive this.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax

from repro.configs.base import ModelConfig
from repro.core.network import CHANNEL_CATALOGUE, MultiChannelLink
from repro.core.orchestrator import Orchestrator, TaskSpec
from repro.core.perf_model import DEVICE_CATALOGUE, DeviceSpec
from repro.core.resource import DeviceHandle, DeviceRegistry
from repro.core import trustzones as tz
from repro.serving.engine import EdgeServingEngine, Request, ServeConfig
from repro.training import federated as fed


def default_home(hub_name: str = "hub") -> DeviceRegistry:
    """A representative smart home: hub + phones + TV + wearable + IoT."""
    reg = DeviceRegistry()
    wifi = [CHANNEL_CATALOGUE["wifi6"]]
    multi = [CHANNEL_CATALOGUE["wifi6"], CHANNEL_CATALOGUE["uwb"]]
    ble = [CHANNEL_CATALOGUE["ble"]]
    zig = [CHANNEL_CATALOGUE["zigbee"]]

    def dev(cat, link, zone="household", owner="alice"):
        return DeviceHandle(spec=DEVICE_CATALOGUE[cat],
                            link=MultiChannelLink(link),
                            zone=zone, owner=owner)

    reg.register(hub_name, dev("edgeai-hub", multi))
    reg.register("alice-phone", dev("flagship-phone", wifi,
                                    zone="personal", owner="alice"))
    reg.register("bob-phone", dev("mid-phone", wifi,
                                  zone="personal", owner="bob"))
    reg.register("living-room-tv", dev("smart-tv", wifi))
    reg.register("alice-watch", dev("wearable", ble,
                                    zone="personal", owner="alice"))
    reg.register("door-sensor", dev("iot-sensor", zig))
    reg.register("vacuum", dev("robot-vacuum", wifi))
    reg.register("bob-old-phone", dev("old-phone", wifi,
                                      zone="household", owner="bob"))
    return reg


@dataclass
class EdgeAIHub:
    registry: DeviceRegistry
    orchestrator: Orchestrator
    hub_device: str = "hub"
    engines: dict = field(default_factory=dict)

    @classmethod
    def create(cls, hub_name: str = "hub", policy: str = "priority"):
        reg = default_home(hub_name)
        orch = Orchestrator(reg, hub_device=hub_name, policy=policy)
        return cls(registry=reg, orchestrator=orch, hub_device=hub_name)

    # -- serving ----------------------------------------------------------
    def deploy_model(self, name: str, cfg: ModelConfig, params,
                     scfg: Optional[ServeConfig] = None) -> EdgeServingEngine:
        eng = EdgeServingEngine(cfg, params, scfg or ServeConfig())
        self.engines[name] = eng
        return eng

    def serve(self, name: str, req: Request) -> None:
        self.engines[name].submit(req)

    # -- federated rounds (orchestrator picks eligible clients) -----------
    def federated_round(self, cfg: ModelConfig, fcfg: fed.FedConfig, params,
                        client_data: dict, data_item: tz.DataItem,
                        round_idx: int = 0):
        devices = {n: (self.registry.get(n).zone, self.registry.get(n).owner)
                   for n in self.registry.available()}
        eligible = tz.filter_devices(data_item, devices)
        chosen = {n: client_data[n] for n in sorted(client_data)
                  if n in eligible}
        if not chosen:
            raise tz.AccessError("no trust-zone-eligible clients")
        return fed.fed_round(cfg, fcfg, params,
                             {i: v for i, v in enumerate(chosen.values())},
                             round_idx)

    # -- task submission ----------------------------------------------------
    def submit(self, spec: TaskSpec) -> int:
        return self.orchestrator.submit(spec)

    def run(self, until: float = float("inf")) -> dict:
        return self.orchestrator.run(until)
