"""Trust zones + ACLs shaping Edge-AI data flow (paper Fig. 4).

Data items carry a zone label and an ACL; devices belong to zones and
owners.  ``allowed(data, device)`` is the single enforcement point the
orchestrator consults before moving tensors, model updates, or context
between devices ("access to sensitive data remains controlled").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

# built-in zone lattice: device zone -> data zones it may process.
# public data flows anywhere; work is an island; personal data
# additionally requires the device owner to match (see ``allowed``).
DEFAULT_FLOW = {
    "personal": {"personal", "household", "public"},
    "household": {"personal", "household", "public"},
    "work": {"work", "public"},
    "public": {"public"},
}


@dataclass(frozen=True)
class DataItem:
    name: str
    zone: str                       # sensitivity label of the data
    owner: str
    acl_allow: frozenset = frozenset()   # device names explicitly allowed
    acl_deny: frozenset = frozenset()    # device names explicitly denied


@dataclass(frozen=True)
class ZonePolicy:
    flow: dict = field(default_factory=lambda: dict(DEFAULT_FLOW))

    def zone_allows(self, data_zone: str, device_zone: str) -> bool:
        """May data labelled ``data_zone`` be processed in ``device_zone``?

        Data flows to a device zone iff the device zone is within the
        data's allowed consumers: data of zone Z may be seen by device
        zones D where Z ∈ flow[D] — e.g. 'personal' data only on
        personal devices; 'public' data anywhere.
        """
        return data_zone in self.flow.get(device_zone, set())


class AccessError(PermissionError):
    pass


def allowed(data: DataItem, device_name: str, device_zone: str,
            device_owner: str, policy: Optional[ZonePolicy] = None) -> bool:
    if device_name in data.acl_deny:
        return False
    if device_name in data.acl_allow:
        return True
    policy = policy or ZonePolicy()
    if data.zone == "personal" and device_owner != data.owner:
        return False
    return policy.zone_allows(data.zone, device_zone)


def check(data: DataItem, device_name: str, device_zone: str,
          device_owner: str, policy: Optional[ZonePolicy] = None) -> None:
    if not allowed(data, device_name, device_zone, device_owner, policy):
        raise AccessError(
            f"data {data.name!r} (zone={data.zone}, owner={data.owner}) "
            f"may not flow to device {device_name!r} "
            f"(zone={device_zone}, owner={device_owner})")


def filter_devices(data: DataItem, devices: dict[str, tuple[str, str]],
                   policy: Optional[ZonePolicy] = None) -> list[str]:
    """devices: name -> (zone, owner). Returns the permitted subset —
    e.g. the FL client set for a given training corpus."""
    return [n for n, (z, o) in devices.items()
            if allowed(data, n, z, o, policy)]
