"""Resource manager: device registry with capabilities + dynamic load.

Devices *subscribe* to the hub (paper: "subscription and management of
resources in the local edge"), advertise their ``DeviceSpec`` and
channels, heartbeat their availability and report instantaneous load.
The scheduler reads this to match tasks to resources.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.network import Channel, MultiChannelLink
from repro.core.perf_model import DeviceSpec


@dataclass
class DeviceHandle:
    spec: DeviceSpec
    link: MultiChannelLink
    owner: str = "household"
    zone: str = "household"            # trust zone (core.trustzones)
    available: bool = True
    load: float = 0.0                  # 0..1 instantaneous utilisation
    battery: Optional[float] = None    # 0..1, None = mains-powered
    last_heartbeat: float = 0.0
    queue_depth: int = 0

    @property
    def effective_flops(self) -> float:
        return self.spec.peak_flops * max(0.0, 1.0 - self.load)


class DeviceRegistry:
    """The hub's view of every device at this consumer edge."""

    def __init__(self, heartbeat_timeout: float = 30.0):
        self._devices: dict[str, DeviceHandle] = {}
        self.heartbeat_timeout = heartbeat_timeout

    # -- subscription ---------------------------------------------------
    def register(self, name: str, handle: DeviceHandle) -> None:
        self._devices[name] = handle

    def unregister(self, name: str) -> None:
        self._devices.pop(name, None)

    def heartbeat(self, name: str, now: float, *, load: float = None,
                  battery: float = None) -> None:
        h = self._devices[name]
        h.last_heartbeat = now
        h.available = True
        if load is not None:
            h.load = load
        if battery is not None:
            h.battery = battery

    def sweep(self, now: float) -> list[str]:
        """Mark devices that missed heartbeats unavailable; return them."""
        lost = []
        for name, h in self._devices.items():
            if h.available and now - h.last_heartbeat > self.heartbeat_timeout:
                h.available = False
                lost.append(name)
        return lost

    # -- queries ----------------------------------------------------------
    def get(self, name: str) -> DeviceHandle:
        return self._devices[name]

    def __contains__(self, name: str) -> bool:
        return name in self._devices

    def names(self) -> list[str]:
        return list(self._devices)

    def available(self, *, zone: Optional[str] = None,
                  train_capable: Optional[bool] = None,
                  min_memory: float = 0.0) -> list[str]:
        out = []
        for name, h in self._devices.items():
            if not h.available:
                continue
            if zone is not None and h.zone != zone:
                continue
            if train_capable is not None and \
                    h.spec.train_capable != train_capable:
                continue
            if h.spec.memory_bytes < min_memory:
                continue
            out.append(name)
        return out

    def least_loaded(self, candidates: Optional[list[str]] = None) -> str:
        names = candidates if candidates is not None else self.available()
        if not names:
            raise RuntimeError("no available devices")
        return min(names, key=lambda n: self._devices[n].load)
