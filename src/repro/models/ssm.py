"""Mamba2 (SSD — state-space duality) trunk. [arXiv:2405.21060]

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
intra-chunk term + a `lax.scan` inter-chunk state recurrence, O(L * Q)
total.  Decode carries (conv_state, ssm_state) — O(1) per token, no KV
cache, which is what makes ``long_500k`` tractable for this family.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_mamba_block(cfg: ModelConfig, key, stack=()) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, w = cfg.ssm_heads, cfg.ssm_conv_width
    conv_ch = di + 2 * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": L._dense_init(k1, (d, 2 * di + 2 * n + h), stack),
        "conv_w": L._dense_init(k2, (w, conv_ch), stack, in_axis_size=w),
        "conv_b": L._zeros((conv_ch,), stack),
        "A_log": L._zeros((h,), stack),            # A = -exp(A_log) = -1
        "D": L._ones((h,), stack),
        "dt_bias": L._zeros((h,), stack),
        "gate_norm": L.init_rmsnorm(di, stack),
        "out_proj": L._dense_init(k3, (di, d), stack, in_axis_size=di),
        "ln": L.init_rmsnorm(d, stack),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "embed": L.init_embedding(cfg, k1),
        "unembed": L.init_unembed(cfg, k2),
        "layers": init_mamba_block(cfg, key, stack=(cfg.num_layers,)),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None, use_kernel: bool = False):
    """Chunked SSD scan.

    x: (b, l, h, p); dt: (b, l, h) (post-softplus); A: (h,) negative;
    B, C: (b, l, n) (single group).  h0: optional initial state (b,h,p,n).
    Returns (y (b, l, h, p), h_final (b, h, p, n)).
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.ssd_scan(x, dt, A, B, C, chunk=chunk, h0=h0)
    b, l, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, l)
    orig_l = l
    if l % Q:
        # pad the tail: dt=0 => decay exp(0)=1 and zero state contribution
        pad = Q - l % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        l = l + pad
    nc = l // Q

    xc = x.reshape(b, nc, Q, h, p)
    dtc = dt.reshape(b, nc, Q, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, Q, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, Q, n).astype(jnp.float32)

    dA = dtc * A.astype(jnp.float32)                  # (b, nc, Q, h)
    cums = jnp.cumsum(dA, axis=2)                     # inclusive

    # ---- intra-chunk (attention-like) term
    seg = cums[:, :, :, None, :] - cums[:, :, None, :, :]   # (b,nc,Q,Q,h) i,j
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    xdt = xc.astype(jnp.float32) * dtc[..., None]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, Lmat, xdt)

    # ---- chunk-final states
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)       # (b,nc,Q,h)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, decay_to_end, xdt)

    # ---- inter-chunk recurrence
    chunk_decay = jnp.exp(cums[:, :, -1, :])                # (b,nc,h)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def body(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                   # emit pre-chunk

    h_final, prev = lax.scan(
        body, h0.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)                    # (b,nc,h,p,n)

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, prev, jnp.exp(cums))
    y = (y_intra + y_inter).reshape(b, l, h, p)[:, :orig_l]
    return y.astype(x.dtype), h_final


def _causal_conv(xBC, conv_w, conv_b, conv_state=None, true_len=None):
    """Depthwise causal conv via shifted adds.

    xBC: (b, l, ch); conv_w: (w, ch).  conv_state: (b, w-1, ch) history
    prepended (decode/chunked-prefill continuity) or zeros.
    ``true_len``: optional (b,) — with right-padded input the returned
    state window ends at each row's true boundary (positions
    [n-w+1, n)), not at the pad tail.
    Returns (out (b, l, ch), new_state (b, w-1, ch)).
    """
    b, l, ch = xBC.shape
    w = conv_w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((b, w - 1, ch), xBC.dtype)
    full = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    out = jnp.zeros((b, l, ch), xBC.dtype)
    for i in range(w):
        out = out + full[:, i:i + l] * conv_w[i].astype(xBC.dtype)
    out = out + conv_b.astype(xBC.dtype)
    if w <= 1:
        return out, conv_state
    if true_len is None:
        return out, full[:, -(w - 1):]
    # position p lives at full[:, p + w - 1]; window [n-w+1, n) starts
    # at full index n, and negative positions land in the zero prefix
    idx = true_len[:, None] + jnp.arange(w - 1, dtype=jnp.int32)[None, :]
    new_state = jnp.take_along_axis(full, idx[..., None], axis=1)
    return out, new_state


def _split_in_proj(cfg: ModelConfig, zxbcdt):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, xBC, dt


def mamba_mix(cfg: ModelConfig, p: Params, x, state=None, *,
              use_kernel: bool = False, true_len=None):
    """Sequence-mode mamba2 mixer. x: (b, l, d).

    state: optional dict(conv=(b,w-1,ch), ssm=(b,h,pd,n)) for continuation.
    ``true_len``: optional (b,) int32 — positions >= true_len are
    right-padding: their dt is forced to 0, which makes them exact
    no-ops on the recurrence (decay exp(0·A)=1, zero state update), and
    the conv state is taken at the true boundary.
    Returns (out (b,l,d), new_state dict).
    """
    b, l, d = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(x.dtype))
    z, xBC, dt = _split_in_proj(cfg, zxbcdt)

    conv_in = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_in,
                                 true_len=true_len)
    xBC = jax.nn.silu(xBC)
    xin = xBC[..., :di].reshape(b, l, h, pd)
    B = xBC[..., di:di + n]
    C = xBC[..., di + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    if true_len is not None:
        tmask = jnp.arange(l, dtype=jnp.int32)[None, :] < true_len[:, None]
        dt = jnp.where(tmask[..., None], dt, 0.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h0 = None if state is None else state["ssm"]
    y, h_final = ssd_chunked(xin, dt, A, B, C, cfg.ssm_chunk, h0=h0,
                             use_kernel=use_kernel)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xin
    y = y.reshape(b, l, di)
    y = L.rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(x.dtype))
    return out, {"conv": new_conv, "ssm": h_final}


def mamba_mix_decode(cfg: ModelConfig, p: Params, x, state):
    """Single-step mixer. x: (b, 1, d); state dict as above."""
    b, _, d = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(x.dtype))
    z, xBC, dt = _split_in_proj(cfg, zxbcdt)
    xBC = xBC[:, 0]                                    # (b, ch)

    conv_state = state["conv"]                         # (b, w-1, ch)
    full = jnp.concatenate(
        [conv_state.astype(xBC.dtype), xBC[:, None]], axis=1)  # (b, w, ch)
    conv_out = jnp.einsum("bwc,wc->bc", full, p["conv_w"].astype(xBC.dtype)) \
        + p["conv_b"].astype(xBC.dtype)
    new_conv = full[:, 1:]
    xBC = jax.nn.silu(conv_out)
    xin = xBC[..., :di].reshape(b, h, pd)
    B = xBC[..., di:di + n].astype(jnp.float32)
    C = xBC[..., di + n:].astype(jnp.float32)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (b, h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                # (b, h)
    hs = state["ssm"].astype(jnp.float32)              # (b, h, pd, n)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xin.astype(jnp.float32), B)
    hs = hs * a[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", hs, C)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = L.rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(x.dtype))
    return out, {"conv": new_conv, "ssm": hs}


# ---------------------------------------------------------------------------
# blocks & trunk
# ---------------------------------------------------------------------------

def block_fwd(cfg: ModelConfig, p: Params, x, state=None, *,
              use_kernel=False, true_len=None):
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    o, new_state = mamba_mix(cfg, p, h, state, use_kernel=use_kernel,
                             true_len=true_len)
    return x + o, new_state


def block_decode(cfg: ModelConfig, p: Params, x, state):
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    o, new_state = mamba_mix_decode(cfg, p, h, state)
    return x + o, new_state


def init_state(cfg: ModelConfig, batch: int, stack=()) -> Params:
    ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": L._zeros((batch, cfg.ssm_conv_width - 1, ch), stack,
                         cfg.activation_dtype),
        "ssm": L._zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), stack, jnp.float32),
    }


def forward(cfg: ModelConfig, params: Params, tokens, *, use_kernel=False,
            remat: Optional[str] = None):
    from repro.models.transformer import _maybe_remat
    x = L.embed(cfg, params["embed"], tokens)

    def body(h, lp):
        h, _ = block_fwd(cfg, lp, h, use_kernel=use_kernel)
        return h, None
    x, _ = lax.scan(_maybe_remat(body, remat), x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(cfg, params["embed"], params["unembed"], x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    del max_len  # O(1) state — the SSM's whole point
    return {"layers": init_state(cfg, batch, stack=(cfg.num_layers,))}


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     num_blocks: int, block_size: int,
                     kv_dtype=None) -> Params:
    """SSM state is O(1) — there are no KV pages to allocate; the paged
    cache is the dense one and the engine's pool sees zero demand
    (``kv_dtype`` is accepted and ignored: no pages, nothing to
    quantize)."""
    del num_blocks, block_size, kv_dtype
    return init_cache(cfg, batch, max_len)


def decode_step_paged(cfg: ModelConfig, params: Params, cache: Params,
                      tokens, pos, block_tables, use_pallas: bool = False):
    del block_tables, use_pallas  # no attention, nothing paged
    return decode_step(cfg, params, cache, tokens, pos)


def extend_paged(cfg: ModelConfig, params: Params, cache: Params, tokens,
                 pos, block_tables, valid_len=None,
                 use_pallas: bool = False):
    """SSM decode state is an O(1) recurrence: scoring S tokens advances
    it irreversibly, and a rejected speculation could not roll back by
    position masking the way paged KV does.  Gated out of the
    speculative path via ``model.spec_decodable`` / ``model.extendable``
    — catch-up prefill for this family stays one token per step."""
    raise NotImplementedError(
        "ssm has no multi-token extend: recurrent state cannot roll back")


extend = extend_paged  # the dense twin is gated identically


def prefill_paged(cfg: ModelConfig, params: Params, tokens, max_len,
                  cache, *, slots, write_tables=None, ctx_tables=None,
                  ctx_len=None, true_len=None, use_kernel=False):
    """Admission prefill fused with state insertion: the O(1) SSM state
    rows land directly in the engine cache at ``slots``.  There are no
    KV pages and no shareable prefix state (the recurrence is not
    reconstructible from pages), so context is rejected."""
    if write_tables is not None or ctx_tables is not None:
        raise ValueError("ssm has no paged KV and no shareable prefix")
    from repro.models.transformer import scatter_cache_rows
    logits, states = prefill(cfg, params, tokens, max_len,
                             use_kernel=use_kernel, true_len=true_len)
    slots = jnp.asarray(slots, jnp.int32)
    return logits, dict(cache, layers=scatter_cache_rows(
        cache["layers"], states["layers"], slots, 1))


def decode_step(cfg: ModelConfig, params: Params, cache: Params, tokens, pos):
    del pos  # state is positionless
    x = L.embed(cfg, params["embed"], tokens)

    def body(h, inp):
        lp, st = inp
        h, st2 = block_decode(cfg, lp, h, st)
        return h, st2
    x, new_states = lax.scan(body, x, (params["layers"], cache["layers"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, {"layers": new_states}


def prefill(cfg: ModelConfig, params: Params, tokens, max_len, *,
            use_kernel=False, true_len=None):
    del max_len
    from repro.models.transformer import broadcast_true_len, gather_last
    x = L.embed(cfg, params["embed"], tokens)
    n = broadcast_true_len(true_len, x.shape[0])

    def body(h, lp):
        h, st = block_fwd(cfg, lp, h, use_kernel=use_kernel, true_len=n)
        return h, st
    x, states = lax.scan(body, x, params["layers"])
    x = x[:, -1:] if n is None else gather_last(x, n)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, {"layers": states}
