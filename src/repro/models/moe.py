"""Mixture-of-Experts trunk (kimi-k2, granite-moe).

TPU-native expert-parallel design: token->expert dispatch and combine are
*gathers* against an (E, capacity, d) expert buffer, built from a small
integer scatter.  The expert dimension shards over the ``model`` mesh
axis; XLA SPMD inserts the dispatch/combine all-to-alls.  Capacity-based
token dropping (GShard/Switch style) keeps every shape static.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

Params = dict


# ---------------------------------------------------------------------------
# router + expert FFN
# ---------------------------------------------------------------------------

def init_moe_mlp(cfg: ModelConfig, key, stack=()) -> Params:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": L._dense_init(k1, (d, E), stack),
        "w_gate": L._dense_init(k2, (E, d, f), stack, in_axis_size=d),
        "w_up": L._dense_init(k3, (E, d, f), stack, in_axis_size=d),
        "w_down": L._dense_init(k4, (E, f, d), stack, in_axis_size=f),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.init_mlp(
            cfg, k5, d_ff=cfg.moe_d_ff * cfg.num_shared_experts, stack=stack)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.num_experts_per_tok * cfg.capacity_factor
                  / cfg.num_experts)
    return max(c, 1)


def moe_mlp(cfg: ModelConfig, p: Params, x, token_mask=None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar f32).
    ``token_mask``: optional (B, S) bool — False marks padding tokens
    excluded from expert capacity (see ``_moe_tokens``).

    cfg.moe_rowwise: dispatch each sequence independently (vmap over
    batch) — the expert buffers then carry the batch dim and shard over
    `data`, instead of one GLOBAL (E, c) buffer that every model-shard
    must process in full (16x redundant expert FLOPs on the 16-way data
    mesh; EXPERIMENTS.md §Perf).  Per-row capacity is the usual
    trade-off (slightly higher dropping variance).
    """
    B, S, d = x.shape
    if token_mask is not None:
        token_mask = token_mask.reshape(B, S)
    if cfg.moe_rowwise:
        out, aux = jax.vmap(
            lambda row, m: _moe_tokens(cfg, p, row, m))(
                x.reshape(B, S, d),
                (jnp.ones((B, S), bool) if token_mask is None
                 else token_mask))
        return out.reshape(B, S, d), jnp.mean(aux)
    flat_mask = None if token_mask is None else token_mask.reshape(B * S)
    out, aux = _moe_tokens(cfg, p, x.reshape(B * S, d), flat_mask)
    return out.reshape(B, S, d), aux


def _moe_tokens(cfg: ModelConfig, p: Params, xf, token_mask=None):
    """Capacity-based top-k dispatch over a flat token set xf: (N, d).

    ``token_mask``: optional (N,) bool — False rows (padding) are routed
    to a sentinel expert id E so they never occupy real expert capacity
    (a pad stealing a capacity slot would silently drop a REAL token and
    change its output — padded prefill must be exact).
    """
    N, d = xf.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    c = capacity(cfg, N)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (N, E)
    gate, eidx = lax.top_k(probs, k)                           # (N, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    if token_mask is not None:
        eidx = jnp.where(token_mask[:, None], eidx, E)
        gate = gate * token_mask[:, None].astype(gate.dtype)

    # load-balancing auxiliary loss (Switch): E * <f_e> . <p_e>
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce)

    # position of each (token, choice) within its expert, via stable sort
    ef = eidx.reshape(-1)                                      # (N*k,)
    order = jnp.argsort(ef, stable=True)                       # (N*k,)
    es = ef[order]
    idx = jnp.arange(N * k, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), es[1:] != es[:-1]])
    seg_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_start, idx, 0))
    pos_sorted = idx - seg_start
    pos = jnp.zeros((N * k,), jnp.int32).at[order].set(pos_sorted)
    pos = pos.reshape(N, k)
    valid = pos < c

    # dispatch: (E, c) inverse map expert-slot -> token row (sentinel N)
    tok = jnp.broadcast_to(idx.reshape(N, k)[:, :1] * 0
                           + jnp.arange(N, dtype=jnp.int32)[:, None], (N, k))
    inv = jnp.full((E, c), N, jnp.int32)
    inv = inv.at[eidx, jnp.where(valid, pos, c)].set(tok, mode="drop")
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xs = x_pad[inv]                                            # (E, c, d)

    # expert FFN (E-sharded einsums); expert weights may be int8 dicts
    # ({"q", "scale"}, layers.quantize_matmul_params) — the E-stacked
    # einsum has no 2D matmul form, so dequantize densely here instead
    # of routing through weight_einsum
    def _w(leaf):
        if isinstance(leaf, dict):
            return (leaf["q"].astype(jnp.float32)
                    * leaf["scale"][..., None, :].astype(jnp.float32)
                    ).astype(xf.dtype)
        return leaf.astype(xf.dtype)

    wg = _w(p["w_gate"])
    wu = _w(p["w_up"])
    wd = _w(p["w_down"])
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wg)) \
        * jnp.einsum("ecd,edf->ecf", xs, wu)
    ys = jnp.einsum("ecf,efd->ecd", h, wd)                     # (E, c, d)

    # combine: gather each token's k expert outputs (dropped -> zero row)
    ys_pad = jnp.concatenate(
        [ys, jnp.zeros((E, 1, d), ys.dtype)], axis=1)          # (E, c+1, d)
    slot = jnp.where(valid, pos, c)
    y_tok = ys_pad[eidx, slot]                                 # (N, k, d)
    out = jnp.sum(y_tok * gate.astype(y_tok.dtype)[..., None], axis=1)

    if cfg.num_shared_experts:
        out = out + L.mlp(p["shared"], xf[None]).reshape(N, d)
    return out, aux


# ---------------------------------------------------------------------------
# blocks & trunk
# ---------------------------------------------------------------------------

def init_moe_block(cfg: ModelConfig, key, stack=()) -> Params:
    norm_init, _ = L.make_norm(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.init_attention(cfg, k1, stack),
        "moe": init_moe_mlp(cfg, k2, stack),
        "ln1": norm_init(cfg.d_model, stack),
        "ln2": norm_init(cfg.d_model, stack),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    norm_init, _ = L.make_norm(cfg)
    ks = jax.random.split(key, 5)
    n_moe = cfg.num_layers - cfg.first_dense_layers
    p = {
        "embed": L.init_embedding(cfg, ks[0]),
        "unembed": L.init_unembed(cfg, ks[1]),
        "moe_layers": init_moe_block(cfg, ks[2], stack=(n_moe,)),
        "final_norm": norm_init(cfg.d_model),
    }
    if cfg.first_dense_layers:
        p["dense_layers"] = T.init_block(
            cfg, ks[3], stack=(cfg.first_dense_layers,))
    return p


def moe_block_fwd(cfg: ModelConfig, p: Params, x, positions, *,
                  use_flash=False, token_mask=None):
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, k, v = L.attention_fwd(cfg, p["attn"], h, positions, is_global=True,
                              use_flash=use_flash)
    x = x + a
    h = norm(p["ln2"], x)
    m, aux = moe_mlp(cfg, p["moe"], h, token_mask=token_mask)
    return x + m, aux, (k, v)


def moe_block_decode(cfg: ModelConfig, p: Params, x, cache, pos):
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, new_cache = L.attention_decode(cfg, p["attn"], h, cache, pos,
                                      is_global=True)
    x = x + a
    h = norm(p["ln2"], x)
    m, _ = moe_mlp(cfg, p["moe"], h)
    return x + m, new_cache


def moe_block_decode_paged(cfg: ModelConfig, p: Params, x, cache, pos,
                           block_tables, use_pallas: bool = False):
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, new_cache = L.attention_decode_paged(cfg, p["attn"], h, cache, pos,
                                            block_tables,
                                            use_pallas=use_pallas)
    x = x + a
    h = norm(p["ln2"], x)
    m, _ = moe_mlp(cfg, p["moe"], h)
    return x + m, new_cache


def _extend_token_mask(x, valid_len):
    """(B, S) mask of REAL extend rows: host-side padding must not
    steal expert capacity from real tokens (same contract as padded
    prefill).  The capacity BOUND still derives from the static
    (B * S) shape — the usual carve-out; with capacity ample nothing
    drops and extend == sequential decode."""
    if valid_len is None:
        return None
    B, S, _ = x.shape
    return jnp.arange(S, dtype=jnp.int32)[None, :] < valid_len[:, None]


def moe_block_extend_paged(cfg: ModelConfig, p: Params, x, pos, cache,
                           block_tables, valid_len=None, *,
                           use_pallas: bool = False):
    """``moe_block_decode_paged`` for S tokens at once (speculative
    verify / chunked catch-up)."""
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, new_cache = L.attention_extend_paged(cfg, p["attn"], h, pos, cache,
                                            block_tables, valid_len,
                                            use_pallas=use_pallas)
    x = x + a
    h = norm(p["ln2"], x)
    m, _ = moe_mlp(cfg, p["moe"], h,
                   token_mask=_extend_token_mask(x, valid_len))
    return x + m, new_cache


def moe_block_prefill_paged(cfg: ModelConfig, p: Params, x, positions,
                            pages, write_tables, ctx_tables=None,
                            ctx_len=None, *, use_flash=False,
                            token_mask=None):
    """``moe_block_fwd`` writing attention K/V straight into its page
    pool (and reading a shared-prefix chain on a radix-cache hit)."""
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, new_pages = L.attention_prefill_paged(
        cfg, p["attn"], h, positions, pages, write_tables, ctx_tables,
        ctx_len, use_flash=use_flash)
    x = x + a
    h = norm(p["ln2"], x)
    m, _ = moe_mlp(cfg, p["moe"], h, token_mask=token_mask)
    return x + m, new_pages


def forward(cfg: ModelConfig, params: Params, tokens, *, use_flash=False,
            remat: Optional[str] = None):
    """Returns (logits, aux_loss)."""
    x = L.embed(cfg, params["embed"], tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    if cfg.first_dense_layers:
        def dbody(h, lp):
            return T.block_fwd(cfg, lp, h, positions, is_global=True,
                               use_flash=use_flash), None
        x, _ = lax.scan(T._maybe_remat(dbody, remat), x,
                        params["dense_layers"])

    def body(h, lp):
        h, aux, _ = moe_block_fwd(cfg, lp, h, positions, use_flash=use_flash)
        return h, aux
    x, auxes = lax.scan(T._maybe_remat(body, remat), x, params["moe_layers"])

    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, jnp.mean(auxes)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    n_moe = cfg.num_layers - cfg.first_dense_layers
    c = {"moe_layers": L.init_kv_cache(cfg, batch, max_len, stack=(n_moe,))}
    if cfg.first_dense_layers:
        c["dense_layers"] = L.init_kv_cache(
            cfg, batch, max_len, stack=(cfg.first_dense_layers,))
    return c


def decode_step(cfg: ModelConfig, params: Params, cache: Params, tokens, pos):
    x = L.embed(cfg, params["embed"], tokens)
    new_cache = {}
    if cfg.first_dense_layers:
        def dbody(h, inp):
            lp, cc = inp
            h, c2 = T.block_decode(cfg, lp, h, cc, pos, is_global=True)
            return h, c2
        x, dc = lax.scan(dbody, x, (params["dense_layers"],
                                    cache["dense_layers"]))
        new_cache["dense_layers"] = dc

    def body(h, inp):
        lp, cc = inp
        h, c2 = moe_block_decode(cfg, lp, h, cc, pos)
        return h, c2
    x, mc = lax.scan(body, x, (params["moe_layers"], cache["moe_layers"]))
    new_cache["moe_layers"] = mc

    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     num_blocks: int, block_size: int,
                     kv_dtype=None) -> Params:
    """All MoE attention layers are global: every KV cache is paged."""
    del batch, max_len
    quant = kv_dtype == "int8"
    n_moe = cfg.num_layers - cfg.first_dense_layers
    c = {"moe_layers": L.init_kv_pages(cfg, num_blocks, block_size,
                                       stack=(n_moe,), quant=quant)}
    if cfg.first_dense_layers:
        c["dense_layers"] = L.init_kv_pages(
            cfg, num_blocks, block_size, stack=(cfg.first_dense_layers,),
            quant=quant)
    return c


def decode_step_paged(cfg: ModelConfig, params: Params, cache: Params,
                      tokens, pos, block_tables, use_pallas: bool = False):
    x = L.embed(cfg, params["embed"], tokens)
    new_cache = {}
    if cfg.first_dense_layers:
        def dbody(h, inp):
            lp, cc = inp
            h, c2 = T.block_decode_paged(cfg, lp, h, cc, pos, block_tables,
                                         use_pallas)
            return h, c2
        x, dc = lax.scan(dbody, x, (params["dense_layers"],
                                    cache["dense_layers"]))
        new_cache["dense_layers"] = dc

    def body(h, inp):
        lp, cc = inp
        h, c2 = moe_block_decode_paged(cfg, lp, h, cc, pos, block_tables,
                                       use_pallas)
        return h, c2
    x, mc = lax.scan(body, x, (params["moe_layers"], cache["moe_layers"]))
    new_cache["moe_layers"] = mc

    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache


def extend_paged(cfg: ModelConfig, params: Params, cache: Params, tokens,
                 pos, block_tables, valid_len=None,
                 use_pallas: bool = False):
    """Score S tokens against the paged cache in one call (all MoE
    attention is global => fully paged).  See ``transformer.extend_paged``
    for the row semantics and the ``valid_len`` write-drop contract."""
    x = L.embed(cfg, params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (x.shape[0],))
    new_cache = {}
    if cfg.first_dense_layers:
        def dbody(h, inp):
            lp, cc = inp
            h, c2 = T.block_extend_paged(cfg, lp, h, pos, cc, block_tables,
                                         valid_len, use_pallas=use_pallas)
            return h, c2
        x, dc = lax.scan(dbody, x, (params["dense_layers"],
                                    cache["dense_layers"]))
        new_cache["dense_layers"] = dc

    def body(h, inp):
        lp, cc = inp
        h, c2 = moe_block_extend_paged(cfg, lp, h, pos, cc, block_tables,
                                       valid_len, use_pallas=use_pallas)
        return h, c2
    x, mc = lax.scan(body, x, (params["moe_layers"], cache["moe_layers"]))
    new_cache["moe_layers"] = mc

    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache


def extend(cfg: ModelConfig, params: Params, cache: Params, tokens, pos,
           valid_len=None):
    """Dense twin of ``extend_paged`` (strip caches, same row/write
    semantics)."""
    x = L.embed(cfg, params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (x.shape[0],))
    new_cache = {}
    if cfg.first_dense_layers:
        def dbody(h, inp):
            lp, cc = inp
            h, c2 = T.block_extend(cfg, lp, h, cc, pos, is_global=True,
                                   valid_len=valid_len)
            return h, c2
        x, dc = lax.scan(dbody, x, (params["dense_layers"],
                                    cache["dense_layers"]))
        new_cache["dense_layers"] = dc

    def body(h, inp):
        lp, cc = inp
        _, norm = L.make_norm(cfg)
        hh = norm(lp["ln1"], h)
        a, c2 = L.attention_extend(cfg, lp["attn"], hh, cc, pos,
                                   is_global=True, valid_len=valid_len)
        h = h + a
        hh = norm(lp["ln2"], h)
        m, _ = moe_mlp(cfg, lp["moe"], hh,
                       token_mask=_extend_token_mask(h, valid_len))
        return h + m, c2
    x, mc = lax.scan(body, x, (params["moe_layers"], cache["moe_layers"]))
    new_cache["moe_layers"] = mc

    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: Params, tokens, max_len, *,
            use_flash=False, true_len=None):
    x = L.embed(cfg, params["embed"], tokens)
    B, S, _ = x.shape
    n = T.broadcast_true_len(true_len, B)
    token_mask = (None if n is None else
                  jnp.arange(S, dtype=jnp.int32)[None, :] < n[:, None])
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cache = {}
    if cfg.first_dense_layers:
        def dbody(h, lp):
            h, kv = T.block_prefill(cfg, lp, h, positions, is_global=True,
                                    use_flash=use_flash)
            return h, kv
        x, (ks, vs) = lax.scan(dbody, x, params["dense_layers"])
        cache["dense_layers"] = jax.vmap(
            lambda k, v: T._fill_global(cfg, B, max_len, k, v, n))(ks, vs)

    def body(h, lp):
        h, _, kv = moe_block_fwd(cfg, lp, h, positions, use_flash=use_flash,
                                 token_mask=token_mask)
        return h, kv
    x, (ks, vs) = lax.scan(body, x, params["moe_layers"])
    cache["moe_layers"] = jax.vmap(
        lambda k, v: T._fill_global(cfg, B, max_len, k, v, n))(ks, vs)

    _, norm = L.make_norm(cfg)
    x = x[:, -1:] if n is None else T.gather_last(x, n)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, cache


def prefill_paged(cfg: ModelConfig, params: Params, tokens, max_len,
                  cache, *, slots, write_tables=None, ctx_tables=None,
                  ctx_len=None, true_len=None, use_flash=False):
    """Admission prefill writing K/V straight into the engine cache
    (all MoE attention layers are global => fully paged, so radix
    prefix-cache context is supported).  See ``T.prefill_paged``.

    MoE caveat: expert CAPACITY derives from the static suffix token
    count, so under capacity pressure a hit-admitted suffix can drop a
    different token set than the same tokens inside a cold full-prompt
    prefill — the usual static-shape carve-out (``serving/__init__``);
    with capacity_factor high enough that nothing drops, hits are
    bit-exact like every other family.
    """
    x = L.embed(cfg, params["embed"], tokens)
    B, S, _ = x.shape
    n = T.broadcast_true_len(true_len, B)
    token_mask = (None if n is None else
                  jnp.arange(S, dtype=jnp.int32)[None, :] < n[:, None])
    off = (jnp.zeros((B,), jnp.int32) if ctx_len is None
           else jnp.asarray(ctx_len, jnp.int32))
    positions = off[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    paged = write_tables is not None
    slots = jnp.asarray(slots, jnp.int32)
    new_cache = dict(cache)

    if cfg.first_dense_layers:
        if paged:
            def dbody(h, inp):
                lp, pg = inp
                h, pg2 = T.block_prefill_paged(
                    cfg, lp, h, positions, pg, write_tables, ctx_tables,
                    ctx_len, use_flash=use_flash)
                return h, pg2
            x, dpages = lax.scan(dbody, x, (params["dense_layers"],
                                            cache["dense_layers"]))
            new_cache["dense_layers"] = dpages
        else:
            def dbody(h, lp):
                h, kv = T.block_prefill(cfg, lp, h, positions,
                                        is_global=True, use_flash=use_flash)
                return h, kv
            x, (ks, vs) = lax.scan(dbody, x, params["dense_layers"])
            rows = jax.vmap(lambda k, v: T._fill_global(
                cfg, B, max_len, k, v, n))(ks, vs)
            new_cache["dense_layers"] = T.scatter_cache_rows(
                cache["dense_layers"], rows, slots, 1)

    if paged:
        def body(h, inp):
            lp, pg = inp
            h, pg2 = moe_block_prefill_paged(
                cfg, lp, h, positions, pg, write_tables, ctx_tables,
                ctx_len, use_flash=use_flash, token_mask=token_mask)
            return h, pg2
        x, mpages = lax.scan(body, x, (params["moe_layers"],
                                       cache["moe_layers"]))
        new_cache["moe_layers"] = mpages
    else:
        def body(h, lp):
            h, _, kv = moe_block_fwd(cfg, lp, h, positions,
                                     use_flash=use_flash,
                                     token_mask=token_mask)
            return h, kv
        x, (ks, vs) = lax.scan(body, x, params["moe_layers"])
        rows = jax.vmap(lambda k, v: T._fill_global(
            cfg, B, max_len, k, v, n))(ks, vs)
        new_cache["moe_layers"] = T.scatter_cache_rows(
            cache["moe_layers"], rows, slots, 1)

    _, norm = L.make_norm(cfg)
    x = x[:, -1:] if n is None else T.gather_last(x, n)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache
