"""Zamba2-style hybrid trunk: Mamba2 blocks + a SHARED attention block.

[arXiv:2411.15242] One attention(+MLP) block whose weights are shared
across all of its periodic applications (every ``hybrid_attn_period``-th
position); all other positions are Mamba2 blocks.  81 layers @ period 6
=> 13 super-blocks of (5 mamba + 1 shared-attn) + 3 remainder mamba.

Long-context behaviour: training/prefill use full causal attention (as
the model is trained); serving decode uses a sliding-window ring cache
of ``local_window`` — this is what makes ``long_500k`` sub-quadratic
per DESIGN.md §Arch-applicability (SSM state is O(1) regardless).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T

Params = dict


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    period = cfg.hybrid_attn_period
    nb = cfg.num_layers // period
    rem = cfg.num_layers % period
    p = {
        "embed": L.init_embedding(cfg, ks[0]),
        "unembed": L.init_unembed(cfg, ks[1]),
        "mamba": S.init_mamba_block(cfg, ks[2], stack=(nb, period - 1)),
        "shared_attn": T.init_block(cfg, ks[3]),   # ONE set of weights
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if rem:
        p["rem_mamba"] = S.init_mamba_block(cfg, ks[4], stack=(rem,))
    return p


def _superblocks(cfg: ModelConfig) -> tuple[int, int]:
    period = cfg.hybrid_attn_period
    return cfg.num_layers // period, cfg.num_layers % period


# ---------------------------------------------------------------------------
# forward / prefill / decode
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Params, tokens, *, use_flash=False,
            use_kernel=False, remat: Optional[str] = None):
    x = L.embed(cfg, params["embed"], tokens)
    B, Sq, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    shared = params["shared_attn"]

    def mamba_body(h, lp):
        h, _ = S.block_fwd(cfg, lp, h, use_kernel=use_kernel)
        return h, None

    def super_body(h, mp):
        h, _ = lax.scan(T._maybe_remat(mamba_body, remat), h, mp)
        h = T.block_fwd(cfg, shared, h, positions, is_global=True,
                        use_flash=use_flash)
        return h, None

    x, _ = lax.scan(T._maybe_remat(super_body, remat), x, params["mamba"])
    if "rem_mamba" in params:
        x, _ = lax.scan(T._maybe_remat(mamba_body, remat), x,
                        params["rem_mamba"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(cfg, params["embed"], params["unembed"], x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    nb, rem = _superblocks(cfg)
    W = min(max_len, cfg.local_window)
    c = {
        "mamba": S.init_state(cfg, batch, stack=(nb, cfg.hybrid_attn_period - 1)),
        "attn": L.init_kv_cache(cfg, batch, W, stack=(nb,)),
    }
    if rem:
        c["rem_mamba"] = S.init_state(cfg, batch, stack=(rem,))
    return c


def decode_step(cfg: ModelConfig, params: Params, cache: Params, tokens, pos):
    x = L.embed(cfg, params["embed"], tokens)
    shared = params["shared_attn"]

    def mamba_body(h, inp):
        lp, st = inp
        h, st2 = S.block_decode(cfg, lp, h, st)
        return h, st2

    def super_body(h, inp):
        mp, mst, ac = inp
        h, mst2 = lax.scan(mamba_body, h, (mp, mst))
        h, ac2 = T.block_decode(cfg, shared, h, ac, pos, is_global=False)
        return h, (mst2, ac2)

    x, (new_m, new_a) = lax.scan(
        super_body, x, (params["mamba"], cache["mamba"], cache["attn"]))
    new_cache = {"mamba": new_m, "attn": new_a}
    if "rem_mamba" in params:
        x, rst = lax.scan(mamba_body, x,
                          (params["rem_mamba"], cache["rem_mamba"]))
        new_cache["rem_mamba"] = rst
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     num_blocks: int, block_size: int,
                     kv_dtype=None) -> Params:
    """The shared attention block decodes as a ``local_window`` ring
    (see module docstring) and SSM state is O(1): nothing here uses
    ``max_len`` strips, so there are no pages to carve out — the paged
    cache IS the dense cache and pool demand is zero (``kv_dtype`` is
    accepted and ignored: no pages, nothing to quantize)."""
    del num_blocks, block_size, kv_dtype
    return init_cache(cfg, batch, max_len)


def decode_step_paged(cfg: ModelConfig, params: Params, cache: Params,
                      tokens, pos, block_tables, use_pallas: bool = False):
    del block_tables, use_pallas  # ring + SSM state only; nothing paged
    return decode_step(cfg, params, cache, tokens, pos)


def extend_paged(cfg: ModelConfig, params: Params, cache: Params, tokens,
                 pos, block_tables, valid_len=None,
                 use_pallas: bool = False):
    """Hybrid decode state = SSM recurrences + a shared-attn ring: both
    advance irreversibly (the recurrence cannot roll back, ring writes
    evict window context), so neither speculative verify nor multi-token
    catch-up is offered — see ``model.spec_decodable``."""
    raise NotImplementedError(
        "hybrid has no multi-token extend: recurrent state cannot "
        "roll back")


extend = extend_paged  # the dense twin is gated identically


def prefill_paged(cfg: ModelConfig, params: Params, tokens, max_len,
                  cache, *, slots, write_tables=None, ctx_tables=None,
                  ctx_len=None, true_len=None, use_flash=False,
                  use_kernel=False):
    """Admission prefill fused with state insertion (SSM state + shared
    ring rows at ``slots``).  Nothing here is paged or shareable — the
    ring holds only the last W tokens and the recurrence is not
    reconstructible from pages — so context is rejected."""
    if write_tables is not None or ctx_tables is not None:
        raise ValueError("hybrid has no paged KV and no shareable prefix")
    logits, st = prefill(cfg, params, tokens, max_len, use_flash=use_flash,
                         use_kernel=use_kernel, true_len=true_len)
    slots = jnp.asarray(slots, jnp.int32)
    new_cache = dict(cache)
    new_cache["mamba"] = T.scatter_cache_rows(cache["mamba"], st["mamba"],
                                              slots, 2)
    new_cache["attn"] = T.scatter_cache_rows(cache["attn"], st["attn"],
                                             slots, 1)
    if "rem_mamba" in st:
        new_cache["rem_mamba"] = T.scatter_cache_rows(
            cache["rem_mamba"], st["rem_mamba"], slots, 1)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: Params, tokens, max_len, *,
            use_flash=False, use_kernel=False, true_len=None):
    x = L.embed(cfg, params["embed"], tokens)
    B, Sq, _ = x.shape
    n = T.broadcast_true_len(true_len, B)
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    shared = params["shared_attn"]
    W = min(max_len, cfg.local_window)

    def mamba_body(h, lp):
        h, st = S.block_fwd(cfg, lp, h, use_kernel=use_kernel, true_len=n)
        return h, st

    def super_body(h, mp):
        h, mst = lax.scan(mamba_body, h, mp)
        h, kv = T.block_prefill(cfg, shared, h, positions, is_global=True,
                                use_flash=use_flash)
        return h, (mst, kv)

    x, (mst, (ks, vs)) = lax.scan(super_body, x, params["mamba"])
    fill = jax.vmap(lambda k, v: T._fill_local(
        cfg.replace(local_window=W), B, max_len, k, v, n))
    cache = {"mamba": mst, "attn": fill(ks, vs)}
    if "rem_mamba" in params:
        x, rst = lax.scan(mamba_body, x, params["rem_mamba"])
        cache["rem_mamba"] = rst
    x = x[:, -1:] if n is None else T.gather_last(x, n)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, cache
