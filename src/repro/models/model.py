"""Unified model API over all architecture families.

Every family exposes the same five entry points, dispatched on
``cfg.family``:

    init_params(cfg, key)                      -> params pytree
    apply(cfg, params, batch, **opts)          -> (logits, aux_loss)
    loss_fn(cfg, params, batch, **opts)        -> (loss, metrics)
    init_cache(cfg, batch_size, max_len)       -> cache pytree
    prefill(cfg, params, batch, max_len)       -> (logits, cache)
    decode_step(cfg, params, cache, toks, pos) -> (logits, cache)
    init_paged_cache(cfg, b, max_len, nB, bs)  -> cache w/ paged global KV
    decode_step_paged(cfg, params, cache,
                      toks, pos, block_tables) -> (logits, cache)
    prefill_paged(cfg, params, batch, max_len,
                  cache, slots=..., ...)       -> (logits, cache)
    extend_paged(cfg, params, cache, toks[B,S],
                 pos, block_tables)            -> (logits[B,S,V], cache)
    prefix_sharable(cfg)                       -> bool (radix cache ok?)
    extendable(cfg) / spec_decodable(cfg)      -> bool (multi-token
                                                  extend / spec verify?)

``batch`` is a dict: always ``tokens``/``targets``; plus
``image_embeds`` (vlm) or ``audio_embeds`` (encdec) stub-frontend
embeddings.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec, hybrid, moe, ssm, transformer, vlm

Params = Any

_FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
    "vlm": vlm,
}


def family_module(cfg: ModelConfig):
    return _FAMILIES[cfg.family]


def specialize(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Adapt static config knobs to an input shape (e.g. enc-dec position
    table must cover the assigned decoder length)."""
    if cfg.family == "encdec":
        need = shape.seq_len if shape.kind != "decode" else shape.seq_len
        if cfg.max_target_positions < need:
            cfg = cfg.replace(max_target_positions=need)
    return cfg


# ---------------------------------------------------------------------------
# params / forward
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    return family_module(cfg).init_params(cfg, key)


def apply(cfg: ModelConfig, params: Params, batch: dict, *,
          use_flash: bool = False, use_kernel: bool = False,
          remat: Optional[str] = None):
    """Full-sequence logits + scalar aux loss (0 where n/a)."""
    tokens = batch["tokens"]
    zero = jnp.zeros((), jnp.float32)
    if cfg.family == "dense":
        return transformer.forward(cfg, params, tokens, use_flash=use_flash,
                                   remat=remat), zero
    if cfg.family == "moe":
        return moe.forward(cfg, params, tokens, use_flash=use_flash,
                           remat=remat)
    if cfg.family == "ssm":
        return ssm.forward(cfg, params, tokens, use_kernel=use_kernel,
                           remat=remat), zero
    if cfg.family == "hybrid":
        return hybrid.forward(cfg, params, tokens, use_flash=use_flash,
                              use_kernel=use_kernel, remat=remat), zero
    if cfg.family == "encdec":
        return encdec.forward(cfg, params, tokens, batch["audio_embeds"],
                              use_flash=use_flash, remat=remat), zero
    if cfg.family == "vlm":
        return vlm.forward(cfg, params, tokens, batch["image_embeds"],
                           use_flash=use_flash, remat=remat), zero
    raise ValueError(cfg.family)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict, **opts):
    logits, aux = apply(cfg, params, batch, **opts)
    targets = batch["targets"]
    S_t = targets.shape[1]
    logits = logits[:, -S_t:]  # vlm prepends image tokens; align to text
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = float(nll.size)
    ce = jnp.sum(nll) / denom
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    return family_module(cfg).init_cache(cfg, batch_size, max_len)


def init_paged_cache(cfg: ModelConfig, batch_size: int, max_len: int,
                     num_blocks: int, block_size: int, kv_dtype=None):
    """Decode cache with GLOBAL attention KV in a shared page pool of
    ``num_blocks`` x ``block_size`` tokens (no batch axis on pool
    leaves); local ring windows, SSM state and cross K/V stay dense.
    Serve with ``decode_step_paged``; see ``serving.kv_pool``.

    ``kv_dtype="int8"`` stores pool K/V quantized with per-(page,
    offset, kv-head) f32 scales in parallel ``k_scale``/``v_scale``
    pool leaves (``layers.init_kv_pages(quant=True)``); every paged
    read path dequantizes transparently.  ``None`` keeps the f32 pool.
    """
    return family_module(cfg).init_paged_cache(cfg, batch_size, max_len,
                                               num_blocks, block_size,
                                               kv_dtype=kv_dtype)


def prefill(cfg: ModelConfig, params: Params, batch: dict, max_len: int, *,
            use_flash: bool = False, use_kernel: bool = False,
            true_len=None):
    """Run the prompt and build the decode cache.

    ``true_len``: optional int | (B,) int32 — the true number of TEXT
    tokens per row when ``batch["tokens"]`` is right-padded (bucketed
    serving prefill).  Every family then returns logits at the true last
    prompt token and keeps pad positions out of the decode state, making
    padded prefill bit-exact with an unpadded one.
    """
    tokens = batch["tokens"]
    if cfg.family == "encdec":
        return encdec.prefill(cfg, params, tokens, max_len,
                              audio_embeds=batch["audio_embeds"],
                              use_flash=use_flash, true_len=true_len)
    if cfg.family == "vlm":
        return vlm.prefill(cfg, params, tokens, max_len,
                           image_embeds=batch["image_embeds"],
                           use_flash=use_flash, true_len=true_len)
    if cfg.family == "ssm":
        return ssm.prefill(cfg, params, tokens, max_len,
                           use_kernel=use_kernel, true_len=true_len)
    if cfg.family == "hybrid":
        return hybrid.prefill(cfg, params, tokens, max_len,
                              use_flash=use_flash, use_kernel=use_kernel,
                              true_len=true_len)
    if cfg.family == "moe":
        return moe.prefill(cfg, params, tokens, max_len, use_flash=use_flash,
                           true_len=true_len)
    return transformer.prefill(cfg, params, tokens, max_len,
                               use_flash=use_flash, true_len=true_len)


def decode_step(cfg: ModelConfig, params: Params, cache, tokens, pos):
    return family_module(cfg).decode_step(cfg, params, cache, tokens, pos)


def decode_step_paged(cfg: ModelConfig, params: Params, cache, tokens, pos,
                      block_tables, use_pallas: bool = False):
    """``decode_step`` against ``init_paged_cache``: global-layer KV is
    read/written through ``block_tables`` (B, n_blk) int32 (-1 =
    unallocated).  Token-for-token identical to the dense path when the
    tables cover the same logical positions.  ``use_pallas=True`` reads
    pages through the scalar-prefetched Pallas ``paged_attention``
    kernel instead of the jnp gather (TPU serving path)."""
    return family_module(cfg).decode_step_paged(cfg, params, cache, tokens,
                                                pos, block_tables,
                                                use_pallas)


def extend_paged(cfg: ModelConfig, params: Params, cache, tokens, pos,
                 block_tables, valid_len=None, use_pallas: bool = False):
    """Score S tokens against the paged cache in ONE jitted call —
    the multi-token twin of ``decode_step_paged`` used for speculative
    verify and chunked catch-up prefill.

    tokens: (B, S) int32 at absolute positions ``pos + i`` (pos: (B,)
    per-slot write frontiers); block_tables: the slot's full (B, n_blk)
    table (context AND write span).  Returns (logits (B, S, V),
    new_cache): row ``i`` is the next-token distribution after
    consuming ``tokens[:, :i+1]``.  The context read is masked strictly
    below ``pos`` (pre-write view), so stale K/V from a rejected
    speculation is invisible and rollback is pure bookkeeping; K/V for
    rows ``i < valid_len`` is written at ``pos + i`` (pad rows drop).
    ssm/hybrid raise NotImplementedError — gate callers on
    ``extendable`` / ``spec_decodable``.  ``use_pallas=True`` reads a
    QUANTIZED pool through the fused dequant
    ``kernels.flash_attention.paged_extend_attention`` kernel (no-op on
    an f32 pool, which keeps that path bit-exact).
    """
    return family_module(cfg).extend_paged(cfg, params, cache, tokens,
                                           pos, block_tables, valid_len,
                                           use_pallas=use_pallas)


def extend(cfg: ModelConfig, params: Params, cache, tokens, pos,
           valid_len=None):
    """Dense twin of ``extend_paged``: the same multi-token scoring
    against the DENSE (strip/ring) decode cache — keeps the
    ``ServeConfig.paged=False`` A/B engine wave-for-wave identical to
    the paged one.  Same return contract and gating."""
    return family_module(cfg).extend(cfg, params, cache, tokens, pos,
                                     valid_len)


def extendable(cfg: ModelConfig) -> bool:
    """Does the family implement multi-token ``extend_paged``?  True for
    every attention family (teacher-forced catch-up never needs
    rollback, so gemma-style local rings qualify too — their pre-write
    chunk read preserves sequential eviction semantics); False for the
    recurrent families (ssm, hybrid), whose state cannot be advanced S
    tokens and later truncated."""
    return cfg.family in ("dense", "moe", "vlm", "encdec")


def spec_decodable(cfg: ModelConfig) -> bool:
    """Can this config serve as a speculative-decoding VERIFY model?

    Stronger than ``extendable``: a rejected speculation must roll back
    EXACTLY, which the engine gets for free only where every
    token-position-dependent piece of decode state is masked by
    position (paged KV pages, dense ``slots`` strips) — truncating is
    then pure bookkeeping and stale writes stay invisible until
    overwritten in sequence order.  Local-ring layers fail this (a
    rejected write may have evicted live window context) and ssm/hybrid
    recurrences advance irreversibly, so — mirroring
    ``prefix_sharable`` — those configs never speculate and serve the
    vanilla one-token path instead.
    """
    if cfg.family in ("dense", "vlm"):
        return cfg.pattern_period <= 1
    return cfg.family in ("moe", "encdec")


def prefix_sharable(cfg: ModelConfig) -> bool:
    """Can finished chains be shared through the radix prefix cache?

    True iff every token-position-dependent piece of the decode state
    lives in KV pages (reconstructible for any block-aligned prefix):
    fully-global transformers/VLMs (``pattern_period <= 1``), MoE (all
    attention global) and enc-dec (cross K/V is rebuilt from the audio
    by any suffix prefill; chains are keyed under the audio digest).
    Local-ring (gemma-pattern) and recurrent (ssm/hybrid) state cannot
    be recovered from pages, so those configs never share — the radix
    cache simply stays disabled and admission is the cold path.
    """
    if cfg.family in ("dense", "vlm"):
        return cfg.pattern_period <= 1
    return cfg.family in ("moe", "encdec")


def prefill_paged(cfg: ModelConfig, params: Params, batch: dict, max_len,
                  cache, *, slots, write_tables=None, ctx_tables=None,
                  ctx_len=None, true_len=None, use_flash: bool = False):
    """Admission prefill fused with cache insertion (the paged-serving
    twin of ``prefill``): prompt K/V is written DIRECTLY into the
    engine's cache — global-layer K/V into the shared page pool through
    ``write_tables`` (m, n_wblk), per-slot dense leaves (local rings,
    SSM state, cross K/V) at ``slots`` (m,).  With ``ctx_tables`` /
    ``ctx_len`` the rows are radix-cache-hit SUFFIXES that attend the
    shared prefix's pages and skip its prefill FLOPs entirely (only
    legal when ``prefix_sharable(cfg)``); ``ctx_len`` is TOKEN-granular
    (a hit may start mid-page — the engine pre-forks that page) and
    ``ctx_tables``/``write_tables`` are then both the row's FULL block
    table, read below ``ctx_len`` and scattered into from ``ctx_len``
    (see ``layers.attention_prefill_paged``).  ``write_tables=None`` is
    the dense engine's fused admission.  Returns (last-true-token
    logits, updated cache)."""
    tokens = batch["tokens"]
    kw = dict(slots=slots, write_tables=write_tables,
              ctx_tables=ctx_tables, ctx_len=ctx_len, true_len=true_len)
    if cfg.family == "encdec":
        return encdec.prefill_paged(cfg, params, tokens, max_len, cache,
                                    audio_embeds=batch["audio_embeds"],
                                    use_flash=use_flash, **kw)
    if cfg.family == "vlm":
        return vlm.prefill_paged(cfg, params, tokens, max_len, cache,
                                 image_embeds=batch.get("image_embeds"),
                                 use_flash=use_flash, **kw)
    if cfg.family == "ssm":
        return ssm.prefill_paged(cfg, params, tokens, max_len, cache, **kw)
    if cfg.family == "hybrid":
        return hybrid.prefill_paged(cfg, params, tokens, max_len, cache,
                                    use_flash=use_flash, **kw)
    if cfg.family == "moe":
        return moe.prefill_paged(cfg, params, tokens, max_len, cache,
                                 use_flash=use_flash, **kw)
    return transformer.prefill_paged(cfg, params, tokens, max_len, cache,
                                     use_flash=use_flash, **kw)


# ---------------------------------------------------------------------------
# batch construction
# ---------------------------------------------------------------------------

def make_batch(cfg: ModelConfig, shape: InputShape, key=None) -> dict:
    """Concrete random batch matching ``batch_shapes`` (smoke/e2e use)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    shapes = batch_shapes(cfg, shape)
    out = {}
    for name, sds in shapes.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(sds.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, sds.shape, 0, cfg.vocab_size,
                                           dtype=sds.dtype)
        else:
            out[name] = 0.1 * jax.random.normal(sub, sds.shape, sds.dtype)
    return out


def batch_shapes(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for every model input of a train/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = cfg.activation_dtype
    sds = jax.ShapeDtypeStruct
    if cfg.family == "vlm":
        S_text = S - cfg.num_image_tokens
        return {
            "tokens": sds((B, S_text), i32),
            "image_embeds": sds((B, cfg.num_image_tokens,
                                 cfg.image_embed_dim), act),
            "targets": sds((B, S_text), i32),
        }
    if cfg.family == "encdec":
        return {
            "tokens": sds((B, S), i32),
            "audio_embeds": sds((B, cfg.encoder_seq, cfg.d_model), act),
            "targets": sds((B, S), i32),
        }
    return {"tokens": sds((B, S), i32), "targets": sds((B, S), i32)}


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
