from repro.models import model
from repro.models.model import (
    apply,
    batch_shapes,
    count_params,
    decode_step,
    init_cache,
    init_params,
    loss_fn,
    make_batch,
    prefill,
    specialize,
)

__all__ = [
    "apply", "batch_shapes", "count_params", "decode_step", "init_cache",
    "init_params", "loss_fn", "make_batch", "model", "prefill", "specialize",
]
