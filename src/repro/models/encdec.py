"""Whisper-style encoder-decoder backbone. [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a STUB per the
assignment carve-out: inputs are precomputed frame embeddings
(B, encoder_seq, d_model) delivered by ``input_specs()``.  This module
implements the transformer that consumes them: a non-causal encoder and
a causal decoder with cross-attention.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_enc_block(cfg: ModelConfig, key, stack=()):
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.init_attention(cfg, k1, stack),
        "mlp": L.init_gelu_mlp(cfg, k2, stack),
        "ln1": L.init_layernorm(cfg.d_model, stack),
        "ln2": L.init_layernorm(cfg.d_model, stack),
    }


def _init_dec_block(cfg: ModelConfig, key, stack=()):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_attn": L.init_attention(cfg, k1, stack),
        "cross_attn": L.init_attention(cfg, k2, stack),
        "mlp": L.init_gelu_mlp(cfg, k3, stack),
        "ln1": L.init_layernorm(cfg.d_model, stack),
        "ln2": L.init_layernorm(cfg.d_model, stack),
        "ln3": L.init_layernorm(cfg.d_model, stack),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "embed": L.init_embedding(cfg, ks[0]),
        "pos_table": 0.02 * jax.random.normal(
            ks[1], (cfg.max_target_positions, cfg.d_model)),
        "encoder": _init_enc_block(cfg, ks[2], stack=(cfg.encoder_layers,)),
        "decoder": _init_dec_block(cfg, ks[3], stack=(cfg.num_layers,)),
        "enc_ln": L.init_layernorm(cfg.d_model),
        "dec_ln": L.init_layernorm(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: Params, audio_embeds):
    """audio_embeds: (B, T_enc, d) stub frontend output."""
    B, Te, d = audio_embeds.shape
    x = audio_embeds.astype(cfg.activation_dtype)
    x = x + L.sinusoidal_positions(Te, d).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32), (B, Te))

    def body(h, lp):
        a, _, _ = L.attention_fwd(cfg, lp["attn"],
                                  L.layernorm(lp["ln1"], h, cfg.norm_eps),
                                  positions, is_global=True, causal=False)
        h = h + a
        m = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln2"], h, cfg.norm_eps))
        return h + m, None

    x, _ = lax.scan(body, x, params["encoder"])
    return L.layernorm(params["enc_ln"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _dec_block_fwd(cfg, lp, h, positions, enc_out, use_flash=False):
    a, k, v = L.attention_fwd(cfg, lp["self_attn"],
                              L.layernorm(lp["ln1"], h, cfg.norm_eps),
                              positions, is_global=True, use_flash=use_flash)
    h = h + a
    c, ck, cv = L.attention_fwd(cfg, lp["cross_attn"],
                                L.layernorm(lp["ln2"], h, cfg.norm_eps),
                                positions, is_global=True, kv_x=enc_out)
    h = h + c
    m = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln3"], h, cfg.norm_eps))
    return h + m, (k, v, ck, cv)


def forward(cfg: ModelConfig, params: Params, tokens, audio_embeds, *,
            use_flash=False, remat: Optional[str] = None):
    """Teacher-forced decoder logits. tokens: (B, S_dec)."""
    from repro.models.transformer import _maybe_remat
    enc_out = encode(cfg, params, audio_embeds)
    B, Sq = tokens.shape
    x = L.embed(cfg, params["embed"], tokens)
    x = x + params["pos_table"][:Sq].astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))

    def body(h, lp):
        h, _ = _dec_block_fwd(cfg, lp, h, positions, enc_out,
                              use_flash=use_flash)
        return h, None

    x, _ = lax.scan(_maybe_remat(body, remat), x, params["decoder"])
    x = L.layernorm(params["dec_ln"], x, cfg.norm_eps)
    return L.unembed(cfg, params["embed"], {}, x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    Ld = cfg.num_layers
    return {
        "self": L.init_kv_cache(cfg, batch, max_len, stack=(Ld,)),
        "cross_k": L._zeros((Ld, batch, cfg.encoder_seq, cfg.num_kv_heads,
                             cfg.head_dim), (), cfg.activation_dtype),
        "cross_v": L._zeros((Ld, batch, cfg.encoder_seq, cfg.num_kv_heads,
                             cfg.head_dim), (), cfg.activation_dtype),
    }


def decode_step(cfg: ModelConfig, params: Params, cache: Params, tokens, pos):
    """tokens: (B, 1). Cross K/V precomputed at prefill time."""
    B = tokens.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = L.embed(cfg, params["embed"], tokens)
    x = x + params["pos_table"][pos_b].astype(x.dtype)[:, None, :]

    def body(h, inp):
        lp, sc, ck, cv = inp
        a, sc2 = L.attention_decode(cfg, lp["self_attn"],
                                    L.layernorm(lp["ln1"], h, cfg.norm_eps),
                                    sc, pos, is_global=True)
        h = h + a
        c, _ = L.attention_decode(cfg, lp["cross_attn"],
                                  L.layernorm(lp["ln2"], h, cfg.norm_eps),
                                  sc, pos, is_global=True,
                                  cross_kv=(ck.astype(h.dtype),
                                            cv.astype(h.dtype)))
        h = h + c
        m = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln3"], h, cfg.norm_eps))
        return h + m, sc2

    x, new_self = lax.scan(
        body, x,
        (params["decoder"], cache["self"], cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache, self=new_self)
    x = L.layernorm(params["dec_ln"], x, cfg.norm_eps)
    return L.unembed(cfg, params["embed"], {}, x), new_cache


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     num_blocks: int, block_size: int,
                     kv_dtype=None) -> Params:
    """Decoder self-attention KV is paged; cross K/V stays dense (it is
    encoder-length, written once at prefill and never grows — only the
    self-attn pool quantizes under ``kv_dtype="int8"``)."""
    del max_len
    Ld = cfg.num_layers
    return {
        "self": L.init_kv_pages(cfg, num_blocks, block_size, stack=(Ld,),
                                quant=kv_dtype == "int8"),
        "cross_k": L._zeros((Ld, batch, cfg.encoder_seq, cfg.num_kv_heads,
                             cfg.head_dim), (), cfg.activation_dtype),
        "cross_v": L._zeros((Ld, batch, cfg.encoder_seq, cfg.num_kv_heads,
                             cfg.head_dim), (), cfg.activation_dtype),
    }


def decode_step_paged(cfg: ModelConfig, params: Params, cache: Params,
                      tokens, pos, block_tables, use_pallas: bool = False):
    """Paged twin of ``decode_step``: self-attn KV via block tables."""
    B = tokens.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = L.embed(cfg, params["embed"], tokens)
    x = x + params["pos_table"][pos_b].astype(x.dtype)[:, None, :]

    def body(h, inp):
        lp, sc, ck, cv = inp
        a, sc2 = L.attention_decode_paged(
            cfg, lp["self_attn"], L.layernorm(lp["ln1"], h, cfg.norm_eps),
            sc, pos, block_tables, use_pallas=use_pallas)
        h = h + a
        c, _ = L.attention_decode(cfg, lp["cross_attn"],
                                  L.layernorm(lp["ln2"], h, cfg.norm_eps),
                                  sc, pos, is_global=True,
                                  cross_kv=(ck.astype(h.dtype),
                                            cv.astype(h.dtype)))
        h = h + c
        m = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln3"], h, cfg.norm_eps))
        return h + m, sc2

    x, new_self = lax.scan(
        body, x,
        (params["decoder"], cache["self"], cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache, self=new_self)
    x = L.layernorm(params["dec_ln"], x, cfg.norm_eps)
    return L.unembed(cfg, params["embed"], {}, x), new_cache


def _cross_extend(cfg: ModelConfig, lp, h, ck, cv):
    """Cross-attention for S decoder queries against the precomputed
    per-slot cross K/V (the multi-query twin of ``attention_decode``'s
    ``cross_kv`` branch — no cache update, mask all-ones)."""
    B, S, _ = h.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    scale = cfg.attn_scale if cfg.attn_scale is not None else hd ** -0.5
    q = L.weight_einsum("bsd,dhq->bshq", h, lp["wq"])
    if cfg.use_qk_norm:
        q = L.rmsnorm(lp["q_norm"], q, cfg.norm_eps)
    qg = q.reshape(B, S, K, G, hd)
    Tc = ck.shape[1]
    mask = jnp.ones((1, 1, 1, S, Tc), bool)
    out = L.attention_weights_and_out(qg, ck.astype(h.dtype),
                                      cv.astype(h.dtype), mask,
                                      scale=scale,
                                      softcap=cfg.attn_logit_softcap)
    return L.weight_einsum("bshq,hqd->bsd", out.reshape(B, S, H, hd),
                           lp["wo"])


def extend_paged(cfg: ModelConfig, params: Params, cache: Params, tokens,
                 pos, block_tables, valid_len=None,
                 use_pallas: bool = False):
    """Score S decoder tokens against the paged self-attn cache in one
    call; cross K/V (encoder-length, written at prefill) is read as-is.
    See ``transformer.extend_paged`` for the row semantics."""
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    x = L.embed(cfg, params["embed"], tokens)
    x = x + params["pos_table"][positions].astype(x.dtype)

    def body(h, inp):
        lp, sc, ck, cv = inp
        a, sc2 = L.attention_extend_paged(
            cfg, lp["self_attn"], L.layernorm(lp["ln1"], h, cfg.norm_eps),
            pos, sc, block_tables, valid_len, use_pallas=use_pallas)
        h = h + a
        c = _cross_extend(cfg, lp["cross_attn"],
                          L.layernorm(lp["ln2"], h, cfg.norm_eps), ck, cv)
        h = h + c
        m = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln3"], h, cfg.norm_eps))
        return h + m, sc2

    x, new_self = lax.scan(
        body, x,
        (params["decoder"], cache["self"], cache["cross_k"],
         cache["cross_v"]))
    new_cache = dict(cache, self=new_self)
    x = L.layernorm(params["dec_ln"], x, cfg.norm_eps)
    return L.unembed(cfg, params["embed"], {}, x), new_cache


def extend(cfg: ModelConfig, params: Params, cache: Params, tokens, pos,
           valid_len=None):
    """Dense twin of ``extend_paged`` (strip self-attn caches)."""
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    x = L.embed(cfg, params["embed"], tokens)
    x = x + params["pos_table"][positions].astype(x.dtype)

    def body(h, inp):
        lp, sc, ck, cv = inp
        a, sc2 = L.attention_extend(
            cfg, lp["self_attn"], L.layernorm(lp["ln1"], h, cfg.norm_eps),
            sc, pos, is_global=True, valid_len=valid_len)
        h = h + a
        c = _cross_extend(cfg, lp["cross_attn"],
                          L.layernorm(lp["ln2"], h, cfg.norm_eps), ck, cv)
        h = h + c
        m = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln3"], h, cfg.norm_eps))
        return h + m, sc2

    x, new_self = lax.scan(
        body, x,
        (params["decoder"], cache["self"], cache["cross_k"],
         cache["cross_v"]))
    new_cache = dict(cache, self=new_self)
    x = L.layernorm(params["dec_ln"], x, cfg.norm_eps)
    return L.unembed(cfg, params["embed"], {}, x), new_cache


def prefill(cfg: ModelConfig, params: Params, tokens, max_len, *,
            audio_embeds=None, use_flash=False, true_len=None):
    """Encode audio, run the prompt tokens, build decode cache."""
    from repro.models.transformer import (_fill_global, broadcast_true_len,
                                          gather_last)
    enc_out = encode(cfg, params, audio_embeds)
    B, Sq = tokens.shape
    n = broadcast_true_len(true_len, B)
    x = L.embed(cfg, params["embed"], tokens)
    x = x + params["pos_table"][:Sq].astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))

    def body(h, lp):
        h, kvs = _dec_block_fwd(cfg, lp, h, positions, enc_out,
                                use_flash=use_flash)
        return h, kvs

    x, (ks, vs, cks, cvs) = lax.scan(body, x, params["decoder"])
    cache = {
        "self": jax.vmap(
            lambda k, v: _fill_global(cfg, B, max_len, k, v, n))(ks, vs),
        "cross_k": cks,
        "cross_v": cvs,
    }
    x = x[:, -1:] if n is None else gather_last(x, n)
    x = L.layernorm(params["dec_ln"], x, cfg.norm_eps)
    return L.unembed(cfg, params["embed"], {}, x), cache


def prefill_paged(cfg: ModelConfig, params: Params, tokens, max_len,
                  cache, *, slots, write_tables=None, ctx_tables=None,
                  ctx_len=None, true_len=None, audio_embeds=None,
                  use_flash=False):
    """Admission prefill writing straight into the engine cache:
    decoder self-attn K/V into pages (or dense rows at ``slots``),
    cross K/V — encoder-length, token-position-independent — into its
    dense per-slot rows.

    Prefix-cache hits are sound here because a suffix prefill rebuilds
    the FULL cross K/V from ``audio_embeds`` regardless of which
    decoder tokens it runs, and self-attn prefix K/V comes from pages;
    the engine keys chains under the audio digest so only requests with
    identical audio share (decoder K/V depends on the encoder output
    through cross-attention).
    """
    from repro.models.transformer import (broadcast_true_len, gather_last,
                                          scatter_cache_rows, _fill_global)
    enc_out = encode(cfg, params, audio_embeds)
    B, Sq = tokens.shape
    n = broadcast_true_len(true_len, B)
    off = (jnp.zeros((B,), jnp.int32) if ctx_len is None
           else jnp.asarray(ctx_len, jnp.int32))
    positions = off[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]
    x = L.embed(cfg, params["embed"], tokens)
    x = x + params["pos_table"][positions].astype(x.dtype)
    paged = write_tables is not None
    slots = jnp.asarray(slots, jnp.int32)
    new_cache = dict(cache)

    if paged:
        def body(h, inp):
            lp, pg = inp
            a, pg2 = L.attention_prefill_paged(
                cfg, lp["self_attn"],
                L.layernorm(lp["ln1"], h, cfg.norm_eps), positions, pg,
                write_tables, ctx_tables, ctx_len, use_flash=use_flash)
            h = h + a
            c, ck, cv = L.attention_fwd(
                cfg, lp["cross_attn"],
                L.layernorm(lp["ln2"], h, cfg.norm_eps), positions,
                is_global=True, kv_x=enc_out)
            h = h + c
            m = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln3"], h, cfg.norm_eps))
            return h + m, (pg2, ck, cv)
        x, (pages, cks, cvs) = lax.scan(body, x, (params["decoder"],
                                                  cache["self"]))
        new_cache["self"] = pages
    else:
        def body(h, lp):
            h, kvs = _dec_block_fwd(cfg, lp, h, positions, enc_out,
                                    use_flash=use_flash)
            return h, kvs
        x, (ks, vs, cks, cvs) = lax.scan(body, x, params["decoder"])
        rows = jax.vmap(
            lambda k, v: _fill_global(cfg, B, max_len, k, v, n))(ks, vs)
        new_cache["self"] = scatter_cache_rows(cache["self"], rows,
                                               slots, 1)
    new_cache["cross_k"] = L.scatter_rows(cache["cross_k"], cks, slots, 1)
    new_cache["cross_v"] = L.scatter_rows(cache["cross_v"], cvs, slots, 1)

    x = x[:, -1:] if n is None else gather_last(x, n)
    x = L.layernorm(params["dec_ln"], x, cfg.norm_eps)
    return L.unembed(cfg, params["embed"], {}, x), new_cache
