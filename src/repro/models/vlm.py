"""InternVL2-style VLM backbone: stub vision frontend + dense LM trunk.

[arXiv:2404.16821] The InternViT encoder is a STUB per the assignment
carve-out: ``input_specs()`` delivers precomputed patch embeddings
(B, num_image_tokens, image_embed_dim).  This module owns the MLP
projector and delegates the language trunk to ``models.transformer``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

Params = dict


def init_params(cfg: ModelConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = T.init_params(cfg, k1)
    p["projector"] = {
        "w1": L._dense_init(k2, (cfg.image_embed_dim, cfg.d_model)),
        "w2": L._dense_init(k3, (cfg.d_model, cfg.d_model)),
        "ln": L.init_rmsnorm(cfg.image_embed_dim),
    }
    return p


def project(cfg: ModelConfig, p: Params, image_embeds):
    """(B, N_img, image_embed_dim) -> (B, N_img, d_model)."""
    x = image_embeds.astype(cfg.activation_dtype)
    x = L.rmsnorm(p["projector"]["ln"], x, cfg.norm_eps)
    h = jax.nn.gelu(jnp.einsum(
        "bnd,de->bne", x, p["projector"]["w1"].astype(x.dtype)))
    return jnp.einsum("bne,ef->bnf", h, p["projector"]["w2"].astype(x.dtype))


def forward(cfg: ModelConfig, params: Params, tokens, image_embeds, *,
            use_flash=False, remat: Optional[str] = None):
    """tokens: (B, S_text); image_embeds prepended after projection.

    Returns logits over the FULL (img + text) sequence.
    """
    prefix = project(cfg, params, image_embeds)
    return T.forward(cfg, params, tokens, prefix_embeds=prefix,
                     use_flash=use_flash, remat=remat)


init_cache = T.init_cache
decode_step = T.decode_step
init_paged_cache = T.init_paged_cache      # LM trunk owns all KV layers
decode_step_paged = T.decode_step_paged
extend_paged = T.extend_paged  # text-token extend; image prefix is KV-only
extend = T.extend


def prefill(cfg: ModelConfig, params: Params, tokens, max_len, *,
            image_embeds=None, use_flash=False, true_len=None):
    """``true_len`` counts TEXT tokens only; ``T.prefill`` offsets by the
    image-token prefix internally."""
    prefix = project(cfg, params, image_embeds)
    return T.prefill(cfg, params, tokens, max_len, prefix_embeds=prefix,
                     use_flash=use_flash, true_len=true_len)


def prefill_paged(cfg: ModelConfig, params: Params, tokens, max_len,
                  cache, *, slots, write_tables=None, ctx_tables=None,
                  ctx_len=None, true_len=None, image_embeds=None,
                  use_flash=False):
    """Paged admission prefill (see ``T.prefill_paged``).

    Cold rows project and prepend the image prefix as usual.  On a
    radix prefix-cache hit the matched chain always covers the image
    tokens (the engine keys them under the image digest and treats
    shorter matches as misses), so hit rows are pure-text suffixes and
    ``image_embeds`` is ignored — the prefix K/V is read from pages.
    """
    if ctx_tables is not None:
        return T.prefill_paged(
            cfg, params, tokens, max_len, cache, slots=slots,
            write_tables=write_tables, ctx_tables=ctx_tables,
            ctx_len=ctx_len, true_len=true_len, use_flash=use_flash)
    prefix = project(cfg, params, image_embeds)
    return T.prefill_paged(
        cfg, params, tokens, max_len, cache, slots=slots,
        write_tables=write_tables, true_len=true_len,
        prefix_embeds=prefix, use_flash=use_flash)
