"""Shared neural-net building blocks (pure JAX, functional params-as-pytrees).

Conventions
-----------
* Parameters are nested dicts of jnp arrays. Init functions accept a
  ``stack`` tuple prefix so layers can be stacked for ``jax.lax.scan``.
* Activations run in ``cfg.activation_dtype`` (bf16 by default); softmax
  and norms accumulate in float32.
* Attention is GQA throughout: H query heads grouped over K kv heads.
* Projection weights may be int8-quantized ({"q", "scale"} dict leaves,
  ``quantize_matmul_params``); every matmul site goes through
  ``weight_einsum`` which dispatches on the leaf type.
* The paged KV pool has an int8 layout (``init_kv_pages(quant=True)``):
  K/V bytes are int8 with one f32 scale per (page, token offset,
  kv head) riding in parallel ``k_scale``/``v_scale`` pool leaves; all
  paged attention paths detect it via the ``k_scale`` key.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, stack=(), in_axis_size=None, dtype=jnp.float32):
    """Truncated-normal fan-in init, with optional stacking prefix."""
    full = tuple(stack) + tuple(shape)
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, full, dtype)


def _zeros(shape, stack=(), dtype=jnp.float32):
    return jnp.zeros(tuple(stack) + tuple(shape), dtype)


def _ones(shape, stack=(), dtype=jnp.float32):
    return jnp.ones(tuple(stack) + tuple(shape), dtype)


# ---------------------------------------------------------------------------
# int8 quantization: KV pages + projection weights
# ---------------------------------------------------------------------------

KV_QMAX = 127.0


def quantize_kv(x, eps: float = 1e-8):
    """Symmetric int8 quantization of a K/V tensor along ``head_dim``.

    x: (..., hd).  Returns (q int8 (..., hd), scale f32 (...)): one
    scale per head_dim vector — ``scale = max|x| / 127``,
    ``q = round(x / scale)``.  The group is deliberately the head_dim
    vector of ONE (token, kv-head) row: committed page rows are
    write-once (rollback, CoW and in-flight prefix sharing all reason
    over bytes that never change after commit), so a scale must never
    depend on tokens written later — a coarser whole-page scale would
    have to re-quantize committed rows on every incremental
    ``scatter_kv_tokens`` write.  Overhead is 4/hd bytes per element
    (~6% at hd=64) on top of the 4x int8-vs-f32 saving.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / KV_QMAX + eps
    q = jnp.clip(jnp.round(xf / scale[..., None]), -KV_QMAX, KV_QMAX)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype=jnp.float32):
    """Inverse of ``quantize_kv``: q (..., hd) int8, scale (...)."""
    out = q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
    return out.astype(dtype)


def kv_pages_quantized(pages) -> bool:
    """Is this pool dict the int8 layout (scale leaves present)?"""
    return "k_scale" in pages


# weight name -> (contraction dims, output dims), counted from the end
# of the leaf shape (any leading dims are lax.scan stack axes)
QUANT_WEIGHT_DIMS = {
    "wq": (1, 2), "wk": (1, 2), "wv": (1, 2), "wo": (2, 1),
    "w_gate": (1, 1), "w_up": (1, 1), "w_down": (1, 1),
    "w_in": (1, 1), "w_out": (1, 1),
}


def quantize_weight(w, n_in: int, n_out: int):
    """Per-output-channel symmetric int8 quantization of one projection
    weight: the trailing ``n_in`` + ``n_out`` dims are the matmul dims,
    anything before is a stack prefix (kept on BOTH leaves so
    ``lax.scan`` slices quantized layers exactly like f32 ones)."""
    in_axes = tuple(range(w.ndim - n_in - n_out, w.ndim - n_out))
    wf = w.astype(jnp.float32)
    scale = (jnp.max(jnp.abs(wf), axis=in_axes, keepdims=True) / KV_QMAX
             + 1e-12)
    q = jnp.clip(jnp.round(wf / scale), -KV_QMAX, KV_QMAX).astype(jnp.int8)
    return {"q": q, "scale": jnp.squeeze(scale, axis=in_axes)}


def quantize_matmul_params(params):
    """Copy of ``params`` with every attention/MLP projection weight
    replaced by its int8 quantization ({"q", "scale"} dict leaves —
    ``weight_einsum`` dispatches on the dict).  Norms, embeddings and
    biases stay full precision (cheap and precision-critical).  Used to
    quantize a resident draft model's weights (drafts tolerate int8;
    verify logits are untouched, so greedy speculation stays bit-exact
    while draft bytes shrink ~4x)."""
    def walk(node):
        if not isinstance(node, dict):
            return node
        out = {}
        for name, sub in node.items():
            dims = QUANT_WEIGHT_DIMS.get(name)
            if (dims is not None and not isinstance(sub, dict)
                    and sub.ndim >= sum(dims)):
                out[name] = quantize_weight(sub, *dims)
            else:
                out[name] = walk(sub)
        return out
    return walk(params)


def weight_einsum(eq, x, w):
    """``jnp.einsum(eq, x, w.astype(x.dtype))`` where ``w`` may instead
    be an int8-quantized weight ({"q", "scale"}; see
    ``quantize_weight``).  Quantized weights contract through the
    ``kernels.quant_matmul`` Pallas kernel on TPU (int8 HBM -> VREG
    dequant -> bf16 MXU) and through the jnp dequant twin elsewhere —
    both implement the ``kernels.ref.quant_matmul_ref`` semantics.
    Assumes (true for every projection in this module) that ``eq``
    contracts x's trailing dims against w's leading matmul dims in
    order and appends w's output dims.
    """
    if not isinstance(w, dict):
        return jnp.einsum(eq, x, w.astype(x.dtype))
    x_spec, w_spec = eq.split("->")[0].split(",")
    n_in = sum(1 for c in w_spec if c in x_spec)
    q, scale = w["q"], w["scale"]
    kd = math.prod(q.shape[:n_in])
    nd = math.prod(q.shape[n_in:])
    x2 = x.reshape(-1, kd)
    if jax.default_backend() == "tpu":
        from repro.kernels import ops as kernel_ops
        out2 = kernel_ops.quant_matmul(x2, q.reshape(kd, nd),
                                       scale.reshape(nd).astype(jnp.float32),
                                       out_dtype=x.dtype)
    else:
        wf = (q.reshape(kd, nd).astype(jnp.float32)
              * scale.reshape(nd)[None, :].astype(jnp.float32))
        out2 = jnp.dot(x2.astype(jnp.float32), wf).astype(x.dtype)
    return out2.reshape(x.shape[:x.ndim - n_in] + q.shape[n_in:])


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, stack=()):
    return {"scale": _zeros((d,), stack)}  # gemma-style (1 + scale)


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


def init_layernorm(d: int, stack=()):
    return {"scale": _ones((d,), stack), "bias": _zeros((d,), stack)}


def layernorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


def make_norm(cfg: ModelConfig):
    if cfg.use_layernorm:
        return (lambda d, stack=(): init_layernorm(d, stack),
                lambda p, x: layernorm(p, x, cfg.norm_eps))
    return (lambda d, stack=(): init_rmsnorm(d, stack),
            lambda p, x: rmsnorm(p, x, cfg.norm_eps))


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim//2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd//2)
    angles = angles[..., None, :]  # (..., S, 1, hd//2) to broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embedding table (n, d)."""
    half = d // 2
    log_timescale = math.log(10_000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = jnp.arange(n, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, stack=()):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(k1, (d, H, hd), stack, in_axis_size=d),
        "wk": _dense_init(k2, (d, K, hd), stack, in_axis_size=d),
        "wv": _dense_init(k3, (d, K, hd), stack, in_axis_size=d),
        "wo": _dense_init(k4, (H, hd, d), stack, in_axis_size=H * hd),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = init_rmsnorm(hd, stack)
        p["k_norm"] = init_rmsnorm(hd, stack)
    return p


def _softcap(x, cap: float):
    if cap and cap > 0.0:
        return cap * jnp.tanh(x / cap)
    return x


def attention_weights_and_out(q, k, v, mask, *, scale, softcap=0.0):
    """GQA attention core.

    q: (B, S, K, G, hd)   k, v: (B, T, K, hd)   mask: broadcast (B,1,1,S,T)
    returns (B, S, K, G, hd)
    """
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32)
    scores = _softcap(scores * scale, softcap)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


def causal_mask(s: int, t: int, q_offset=0) -> jnp.ndarray:
    """(S, T) causal mask; q position i attends kv positions <= i+q_offset."""
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    return kpos <= qpos


def window_mask(s: int, t: int, window: int, q_offset=0) -> jnp.ndarray:
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    return (kpos <= qpos) & (kpos > qpos - window)


def _project_seq(cfg: ModelConfig, params, x, positions, *,
                 is_global: bool, kv_x=None):
    """Shared q/k/v projection + qk-norm + RoPE for the full-sequence
    paths (``attention_fwd`` and the paged suffix prefill) — one
    definition so both produce bit-identical projections."""
    q = weight_einsum("bsd,dhq->bshq", x, params["wq"])
    src = x if kv_x is None else kv_x
    k = weight_einsum("btd,dkq->btkq", src, params["wk"])
    v = weight_einsum("btd,dkq->btkq", src, params["wv"])

    if cfg.use_qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)

    if not cfg.use_abs_pos and kv_x is None:
        theta = (cfg.rope_theta_global
                 if (is_global and cfg.rope_theta_global) else cfg.rope_theta)
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attention_fwd(cfg: ModelConfig, params, x, positions, *,
                  is_global: bool, kv_x=None, causal: bool = True,
                  use_flash: bool = False):
    """Full-sequence attention (training / prefill).

    x: (B, S, d). kv_x: cross-attention source (B, T, d) or None.
    Local (sliding-window) layers use a chunked implementation when the
    sequence is long enough, giving true O(S*W) cost.
    Returns (out (B,S,d), k, v) — k/v returned for cache construction.
    """
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    scale = cfg.attn_scale if cfg.attn_scale is not None else hd ** -0.5

    q, k, v = _project_seq(cfg, params, x, positions,
                           is_global=is_global, kv_x=kv_x)

    T = k.shape[1]
    qg = q.reshape(B, S, K, G, hd)

    window = 0 if is_global else cfg.local_window
    if kv_x is not None or not causal:
        mask = jnp.ones((S, T), bool)
        out = attention_weights_and_out(qg, k, v, mask[None, None, None],
                                        scale=scale, softcap=cfg.attn_logit_softcap)
    elif use_flash:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.flash_attention(
            qg.reshape(B, S, H, hd), k, v, scale=scale,
            window=window, softcap=cfg.attn_logit_softcap,
        ).reshape(B, S, K, G, hd)
    elif window and S > 2 * window and S % window == 0:
        out = _chunked_local_attention(qg, k, v, window, scale,
                                       cfg.attn_logit_softcap)
    elif S >= BLOCKWISE_THRESHOLD and S % BLOCKWISE_CHUNK == 0 \
            and T % BLOCKWISE_CHUNK == 0:
        # long full-causal prefill: online-softmax blockwise attention —
        # O(S*chunk) live memory instead of an O(S^2) score tensor (the
        # pure-jnp twin of kernels/flash_attention, used where Pallas
        # can't be lowered for the dry-run)
        out = _blockwise_causal_attention(qg, k, v, scale,
                                          cfg.attn_logit_softcap,
                                          chunk=BLOCKWISE_CHUNK)
    else:
        m = (window_mask(S, T, window) if window else causal_mask(S, T))
        out = attention_weights_and_out(qg, k, v, m[None, None, None],
                                        scale=scale, softcap=cfg.attn_logit_softcap)

    out = out.reshape(B, S, H, hd)
    o = weight_einsum("bshq,hqd->bsd", out, params["wo"])
    return o, k, v


def _chunked_local_attention(qg, k, v, window, scale, softcap):
    """Sliding-window attention in O(S * 2W): chunk + previous chunk.

    qg: (B, S, K, G, hd) with S % window == 0.
    """
    B, S, K, G, hd = qg.shape
    W = window
    C = S // W
    qc = qg.reshape(B, C, W, K, G, hd)
    kc = k.reshape(B, C, W, K, hd)
    vc = v.reshape(B, C, W, K, hd)
    # previous chunk (zeros before chunk 0)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kc], axis=2)  # (B, C, 2W, K, hd)
    v2 = jnp.concatenate([vprev, vc], axis=2)

    qpos = jnp.arange(W)[:, None] + W            # within the 2W frame
    kpos = jnp.arange(2 * W)[None, :]
    m = (kpos <= qpos) & (kpos > qpos - W)       # (W, 2W)
    # chunk 0 has no previous chunk
    first = m & (kpos >= W)
    mask = jnp.concatenate(
        [first[None], jnp.broadcast_to(m, (C - 1, W, 2 * W))], axis=0)

    scores = jnp.einsum("bcskgd,bctkd->bckgst", qc, k2,
                        preferred_element_type=jnp.float32)
    scores = _softcap(scores * scale, softcap)
    scores = jnp.where(mask[None, :, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bckgst,bctkd->bcskgd", probs, v2)
    return out.reshape(B, S, K, G, hd)


BLOCKWISE_THRESHOLD = 8192
BLOCKWISE_CHUNK = 1024

# §Perf knob (set by launch builders, process-scoped): sequence-parallel
# blockwise attention.  Tuple (n_shards, NamedSharding for the
# (B, shard, S/shard, K, G, hd) query layout) or None.  Used when query
# heads cannot shard over the model axis: instead of replicating the
# whole attention, each model-shard computes its 1/n slice of the query
# sequence against (once-gathered) full K/V — no redundant FLOPs and
# 1/n of the score HBM traffic per chip.
SEQ_PARALLEL_ATTN = None


def _blockwise_causal_attention(qg, k, v, scale, softcap,
                                chunk: int = BLOCKWISE_CHUNK):
    """Memory-efficient causal attention: lax.scan over (q, kv) chunks
    with a running (max, denom, acc) — the flash algorithm in pure jnp.

    qg: (B, S, K, G, hd); k, v: (B, T, K, hd).  Strictly-above-diagonal
    chunk pairs are masked (not skipped): ~2x upper-triangle FLOPs, but
    O(S * chunk) live memory, which is what prefill_32k needs to fit.

    With SEQ_PARALLEL_ATTN set, the query sequence is sharded over the
    model axis (vmap over shards stays parallel; lax.map inside each
    shard walks its local chunks).
    """
    B, S, K, G, hd = qg.shape
    T = k.shape[1]
    sp = SEQ_PARALLEL_ATTN
    if sp is not None:
        n_sh, shard_sharding = sp
        per = S // n_sh
        if S % n_sh == 0 and per % chunk == 0 and per >= chunk:
            qs = qg.reshape(B, n_sh, per, K, G, hd)
            qs = lax.with_sharding_constraint(qs, shard_sharding)
            offs = jnp.arange(n_sh) * per

            def per_shard(q_shard, off):
                return _blockwise_inner(q_shard, k, v, scale, softcap,
                                        chunk, q_offset=off)
            out = jax.vmap(per_shard, in_axes=(1, 0), out_axes=1)(qs, offs)
            out = lax.with_sharding_constraint(out, shard_sharding)
            return out.reshape(B, S, K, G, hd)
    return _blockwise_inner(qg, k, v, scale, softcap, chunk)


def _blockwise_inner(qg, k, v, scale, softcap, chunk, q_offset=0):
    B, S, K, G, hd = qg.shape
    T = k.shape[1]
    nq, nk = S // chunk, T // chunk
    qc = qg.reshape(B, nq, chunk, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    rel = jnp.arange(chunk)

    def q_block(args):
        qi, q = args  # q: (B, chunk, K, G, hd)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, kb, vb = inp
            s = jnp.einsum("bskgd,btkd->bkgst", q, kb,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            qpos = q_offset + qi * chunk + rel[:, None]
            kpos = kj * chunk + rel[None, :]
            s = jnp.where(kpos <= qpos, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where((kpos <= qpos)[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, chunk, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (jnp.arange(nk), kc, vc))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qg.dtype)
        return out.transpose(0, 3, 1, 2, 4)          # (B, chunk, K, G, hd)

    out = lax.map(q_block, (jnp.arange(nq), qc))     # (nq, B, chunk, ...)
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K, G, hd)


def _decode_project(cfg: ModelConfig, params, x, pos, *, is_global: bool):
    """Shared q/k/v projection + RoPE for the single-token decode paths.

    x: (B, 1, d); pos: (B,) int32.  Returns (q (B,1,H,hd),
    knew (B,1,K,hd), vnew (B,1,K,hd)) — identical math for the dense and
    paged caches, so both decode variants stay bit-for-bit equal.
    """
    q = weight_einsum("bsd,dhq->bshq", x, params["wq"])
    if cfg.use_qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)

    knew = weight_einsum("bsd,dkq->bskq", x, params["wk"])
    vnew = weight_einsum("bsd,dkq->bskq", x, params["wv"])
    if cfg.use_qk_norm:
        knew = rmsnorm(params["k_norm"], knew, cfg.norm_eps)

    if not cfg.use_abs_pos:
        theta = (cfg.rope_theta_global
                 if (is_global and cfg.rope_theta_global) else cfg.rope_theta)
        posb = pos[:, None]
        q = apply_rope(q, posb, theta)
        knew = apply_rope(knew, posb, theta)
    return q, knew, vnew


def attention_decode(cfg: ModelConfig, params, x, cache, pos, *,
                     is_global: bool, cross_kv=None):
    """Single-token decode. x: (B, 1, d); pos: (B,) int32 per-sequence
    write positions (scalars are broadcast) — continuous batching serves
    requests at different depths in one step.

    cache: dict(k=(B, T, K, hd), v=..., slots=(B, T) ring positions) —
    T == seq_len for global layers, T == window for local ring buffers.
    cross_kv: (k, v) for enc-dec cross attention (no cache update).
    Returns (out, new_cache).
    """
    B, S, d = x.shape
    assert S == 1
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    scale = cfg.attn_scale if cfg.attn_scale is not None else hd ** -0.5

    if cross_kv is not None:
        q = weight_einsum("bsd,dhq->bshq", x, params["wq"])
        if cfg.use_qk_norm:
            q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k, v = cross_kv
        qg = q.reshape(B, 1, K, G, hd)
        T = k.shape[1]
        mask = jnp.ones((1, 1, 1, 1, T), bool)
        out = attention_weights_and_out(qg, k, v, mask, scale=scale,
                                        softcap=cfg.attn_logit_softcap)
        o = weight_einsum("bshq,hqd->bsd", out.reshape(B, 1, H, hd),
                          params["wo"])
        return o, cache

    q, knew, vnew = _decode_project(cfg, params, x, pos, is_global=is_global)

    T = cache["k"].shape[1]
    slot = pos % T  # global caches have T == max seq, so slot == pos there
    barange = jnp.arange(B)
    kc = cache["k"].at[barange, slot].set(knew[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[barange, slot].set(vnew[:, 0].astype(cache["v"].dtype))
    slots = cache["slots"].at[barange, slot].set(pos)

    window = 0 if is_global else cfg.local_window
    valid = (slots >= 0) & (slots <= pos[:, None])
    if window:
        valid &= slots > (pos[:, None] - window)
    mask = valid[:, None, None, None, :]  # (B,1,1,1,T)

    qg = q.reshape(B, 1, K, G, hd)
    out = attention_weights_and_out(qg, kc.astype(x.dtype), vc.astype(x.dtype),
                                    mask, scale=scale,
                                    softcap=cfg.attn_logit_softcap)
    o = weight_einsum("bshq,hqd->bsd", out.reshape(B, 1, H, hd),
                      params["wo"])
    return o, {"k": kc, "v": vc, "slots": slots}


def init_kv_cache(cfg: ModelConfig, batch: int, length: int, stack=(),
                  dtype=None):
    """Empty cache dict with stacking prefix (e.g. per layer)."""
    dtype = dtype or cfg.activation_dtype
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": _zeros((batch, length, K, hd), stack, dtype),
        "v": _zeros((batch, length, K, hd), stack, dtype),
        "slots": jnp.full(tuple(stack) + (batch, length), -1, jnp.int32),
    }


def init_kv_pages(cfg: ModelConfig, num_blocks: int, block_size: int,
                  stack=(), dtype=None, quant: bool = False):
    """Paged KV pool for GLOBAL attention layers.

    Physical pages of ``block_size`` tokens shared by every slot; there
    is NO batch axis — ownership lives entirely in the engine's block
    tables (``serving.kv_pool``).  No ``slots`` array either: validity
    is derived from (block_table, pos) at decode time.

    ``quant=True`` stores K/V as int8 with one f32 scale per (page,
    token offset, kv head) head_dim vector riding in parallel
    ``k_scale``/``v_scale`` leaves of shape (nB, bs, K).  The scale
    leaves have the exact pool layout (page-leading, no batch axis), so
    the engine's generic pool-leaf machinery — CoW page copies, chain
    gathers, persistence scatters — applies to them unchanged.
    """
    dtype = dtype or cfg.activation_dtype
    K, hd = cfg.num_kv_heads, cfg.head_dim
    if quant:
        return {
            "k": _zeros((num_blocks, block_size, K, hd), stack, jnp.int8),
            "v": _zeros((num_blocks, block_size, K, hd), stack, jnp.int8),
            "k_scale": _zeros((num_blocks, block_size, K), stack,
                              jnp.float32),
            "v_scale": _zeros((num_blocks, block_size, K), stack,
                              jnp.float32),
        }
    return {
        "k": _zeros((num_blocks, block_size, K, hd), stack, dtype),
        "v": _zeros((num_blocks, block_size, K, hd), stack, dtype),
    }


def scatter_kv_pages(pages, k, v, write_tables):
    """Write a per-row K/V strip straight into the shared page pool.

    pages: dict(k=(nB, bs, K, hd), v=...); k, v: (B, T, K, hd);
    write_tables: (B, n_wblk) int32 physical page per covered logical
    block (-1 = unallocated -> write dropped).  T is right-padded up to
    ``n_wblk * bs`` — pad K/V lands beyond each row's true length and is
    positionally masked at read time, exactly like the dense path's
    ``slots=-1`` padding.
    """
    nB, bs = pages["k"].shape[0], pages["k"].shape[1]
    B, T = k.shape[0], k.shape[1]
    n_wblk = write_tables.shape[1]
    pad = n_wblk * bs - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_wblk, bs, *k.shape[2:])
    vb = v.reshape(B, n_wblk, bs, *v.shape[2:])
    tgt = jnp.where(write_tables >= 0, write_tables, nB)  # nB is OOB
    if kv_pages_quantized(pages):
        kq, ks = quantize_kv(kb)
        vq, vs = quantize_kv(vb)
        return {
            "k": pages["k"].at[tgt].set(kq, mode="drop"),
            "v": pages["v"].at[tgt].set(vq, mode="drop"),
            "k_scale": pages["k_scale"].at[tgt].set(ks, mode="drop"),
            "v_scale": pages["v_scale"].at[tgt].set(vs, mode="drop"),
        }
    return {
        "k": pages["k"].at[tgt].set(kb.astype(pages["k"].dtype),
                                    mode="drop"),
        "v": pages["v"].at[tgt].set(vb.astype(pages["v"].dtype),
                                    mode="drop"),
    }


def gather_kv_pages(pages, ctx_tables):
    """Materialise the logical K/V view of a shared-prefix chain.

    ctx_tables: (B, n_cblk) int32 physical pages (-1 pad rows gather
    garbage the caller masks via ``ctx_len``).  Returns (k, v) each
    (B, n_cblk * bs, K, hd).
    """
    nB, bs = pages["k"].shape[0], pages["k"].shape[1]
    B = ctx_tables.shape[0]
    bt = jnp.clip(ctx_tables, 0, nB - 1)
    kg = pages["k"][bt].reshape(B, -1, *pages["k"].shape[2:])
    vg = pages["v"][bt].reshape(B, -1, *pages["v"].shape[2:])
    if kv_pages_quantized(pages):
        ks = pages["k_scale"][bt].reshape(B, -1, *pages["k_scale"].shape[2:])
        vs = pages["v_scale"][bt].reshape(B, -1, *pages["v_scale"].shape[2:])
        return dequantize_kv(kg, ks), dequantize_kv(vg, vs)
    return kg, vg


def scatter_rows(full, rows, slots, axis: int):
    """Insert ``m`` single-request rows into a batched cache leaf in one
    shot: ``full`` has the slot/batch dimension at ``axis``; ``rows``
    carries the same leaf with ``m`` entries there; ``slots``: (m,)
    int32 slot indices (distinct)."""
    idx = (slice(None),) * axis + (slots,)
    return full.at[idx].set(rows.astype(full.dtype))


def attention_prefill_paged(cfg: ModelConfig, params, x, positions, pages,
                            write_tables, ctx_tables=None, ctx_len=None, *,
                            use_flash: bool = False):
    """Prefill attention for a GLOBAL layer that writes K/V straight
    into the paged pool — and, on a prefix-cache hit, attends the shared
    prefix's pages instead of recomputing them.

    x: (B, S, d) suffix activations; positions: (B, S) ABSOLUTE
    positions (``ctx_len + arange(S)``); pages: this layer's pool dict.

    Cold rows (``ctx_tables=None``): ``write_tables`` is (B, n_wblk) —
    physical pages covering the suffix span from logical block 0 — and
    the compute delegates to ``attention_fwd`` so the cold paged
    admission is the exact same math as the dense-strip path.

    Hit rows: the prefix match is TOKEN-granular, so the suffix write
    starts at ``ctx_len`` which may land mid-page (the engine has
    already CoW-forked that partial page private).  ``ctx_tables`` and
    ``write_tables`` are then BOTH the row's full block table (logical
    block ``i`` -> physical page): the context is the gathered view of
    that table masked to positions ``< ctx_len`` (per row), and the
    suffix K/V is scattered token-by-token at absolute positions
    ``ctx_len + i`` through the same table (``scatter_kv_tokens``) —
    overwriting, in order, exactly the stale tail the mask was hiding.
    Returns (out (B, S, d), new_pages).
    """
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    scale = cfg.attn_scale if cfg.attn_scale is not None else hd ** -0.5

    if ctx_tables is None:
        o, k, v = attention_fwd(cfg, params, x, positions, is_global=True,
                                use_flash=use_flash)
        return o, scatter_kv_pages(pages, k, v, write_tables)

    q, k, v = _project_seq(cfg, params, x, positions, is_global=True)
    quant = kv_pages_quantized(pages)
    if quant:
        # the suffix attends its own int8 round-trip so the hit-path
        # logits match what later decode reads of these pages see
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k = dequantize_kv(kq, ks, k.dtype)
        v = dequantize_kv(vq, vs, v.dtype)
    ck, cv = gather_kv_pages(pages, ctx_tables)
    Tc = ck.shape[1]
    # context part: logical positions [0, Tc) valid where < ctx_len
    # (token-granular — a partial final page contributes exactly its
    # matched tokens; pad rows of a mixed-depth admission group mask
    # out here); suffix part: plain causal within the suffix
    ctx_ok = jnp.arange(Tc, dtype=jnp.int32)[None, :] < ctx_len[:, None]
    mask = jnp.concatenate(
        [jnp.broadcast_to(ctx_ok[:, None, :], (B, S, Tc)),
         jnp.broadcast_to(causal_mask(S, S), (B, S, S))], axis=-1)
    k_all = jnp.concatenate([ck.astype(x.dtype), k], axis=1)
    v_all = jnp.concatenate([cv.astype(x.dtype), v], axis=1)
    qg = q.reshape(B, S, K, G, hd)
    out = attention_weights_and_out(qg, k_all, v_all,
                                    mask[:, None, None], scale=scale,
                                    softcap=cfg.attn_logit_softcap)
    o = weight_einsum("bshq,hqd->bsd", out.reshape(B, S, H, hd),
                      params["wo"])
    if quant:
        return o, _scatter_tokens_quant(pages, kq, ks, vq, vs,
                                        write_tables,
                                        jnp.asarray(ctx_len, jnp.int32))
    return o, scatter_kv_tokens(pages, k, v, write_tables,
                                jnp.asarray(ctx_len, jnp.int32))


def attention_decode_paged(cfg: ModelConfig, params, x, cache, pos,
                           block_tables, *, use_pallas: bool = False):
    """Single-token decode against a paged KV pool (GLOBAL layers only —
    local ring-window layers stay dense at W, SSM state is O(1)).

    ``use_pallas=True`` swaps the jnp gather read for the Pallas
    ``kernels.flash_attention.paged_attention`` kernel (scalar-prefetched
    block tables stream pages into VMEM — the logical K/V view is never
    materialised in HBM); the write path and masking semantics are
    identical, so the two reads agree to kernel accumulation tolerance.

    x: (B, 1, d); pos: (B,) int32 write positions.
    cache: dict(k=(num_blocks, bs, K, hd), v=...) — the shared page pool
    (per-layer once the surrounding scan strips the stack axis).
    block_tables: (B, n_blk) int32 physical page ids per logical block,
    -1 = unallocated.  Logical capacity n_blk * bs equals the engine's
    ``max_len``, so the gathered K/V tensor has the same shape, values
    and mask as the dense path — decode is bit-for-bit identical; only
    HBM residency shrinks from ``max_slots x max_len`` strips to pages
    actually in flight.

    The new token's K/V is scattered into page ``block_tables[b,
    pos//bs]`` at offset ``pos % bs``; rows whose table entry is -1
    (inactive or stalled slots) drop the write so a freed-and-reused
    page can never be corrupted by a stale slot.
    """
    B, S, d = x.shape
    assert S == 1
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    scale = cfg.attn_scale if cfg.attn_scale is not None else hd ** -0.5

    q, knew, vnew = _decode_project(cfg, params, x, pos, is_global=True)

    nB, bs = cache["k"].shape[0], cache["k"].shape[1]
    blk, off = pos // bs, pos % bs
    phys = block_tables[jnp.arange(B), blk]
    wphys = jnp.where(phys >= 0, phys, nB)       # nB is OOB => dropped
    quant = kv_pages_quantized(cache)
    if quant:
        kq1, ks1 = quantize_kv(knew[:, 0])
        vq1, vs1 = quantize_kv(vnew[:, 0])
        kc = cache["k"].at[wphys, off].set(kq1, mode="drop")
        vc = cache["v"].at[wphys, off].set(vq1, mode="drop")
        kcs = cache["k_scale"].at[wphys, off].set(ks1, mode="drop")
        vcs = cache["v_scale"].at[wphys, off].set(vs1, mode="drop")
        new_cache = {"k": kc, "v": vc, "k_scale": kcs, "v_scale": vcs}
    else:
        kc = cache["k"].at[wphys, off].set(
            knew[:, 0].astype(cache["k"].dtype), mode="drop")
        vc = cache["v"].at[wphys, off].set(
            vnew[:, 0].astype(cache["v"].dtype), mode="drop")
        new_cache = {"k": kc, "v": vc}

    if use_pallas:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.paged_attention(
            q[:, 0], kc, vc, block_tables, pos + 1, scale=scale,
            softcap=cfg.attn_logit_softcap,
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"))
        o = weight_einsum("bshq,hqd->bsd", out[:, None].astype(x.dtype),
                          params["wo"])
        return o, new_cache

    # gather the logical view: (B, n_blk*bs, K, hd)
    bt = jnp.clip(block_tables, 0, nB - 1)
    kg = kc[bt].reshape(B, -1, K, hd)
    vg = vc[bt].reshape(B, -1, K, hd)
    if quant:
        kg = dequantize_kv(kg, kcs[bt].reshape(B, -1, K))
        vg = dequantize_kv(vg, vcs[bt].reshape(B, -1, K))
    t = jnp.arange(block_tables.shape[1] * bs, dtype=jnp.int32)
    allocated = jnp.repeat(block_tables >= 0, bs, axis=1)
    valid = allocated & (t[None, :] <= pos[:, None])
    mask = valid[:, None, None, None, :]          # (B,1,1,1,L)

    qg = q.reshape(B, 1, K, G, hd)
    out = attention_weights_and_out(qg, kg.astype(x.dtype),
                                    vg.astype(x.dtype), mask, scale=scale,
                                    softcap=cfg.attn_logit_softcap)
    o = weight_einsum("bshq,hqd->bsd", out.reshape(B, 1, H, hd),
                      params["wo"])
    return o, new_cache


def scatter_kv_tokens(pages, k, v, block_tables, pos, valid_len=None):
    """Write ``S`` consecutive tokens' K/V into the page pool at absolute
    positions ``pos + i`` through each row's block table (the multi-token
    twin of the single-write in ``attention_decode_paged``).

    pages: dict(k=(nB, bs, K, hd), v=...); k, v: (B, S, K, hd);
    block_tables: (B, n_blk) int32 (-1 = unallocated -> write dropped);
    pos: (B,) int32 first write position; valid_len: optional (B,) int32
    — rows ``i >= valid_len`` are host-side padding whose writes are
    dropped (a padded token must never touch a page: on families with
    additional dense ring state the same drop keeps rings clean, and in
    pages it keeps rollback reasoning local to REAL protocol writes).
    Writes past the table's logical span (``n_blk * bs``) are dropped.
    """
    if kv_pages_quantized(pages):
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return _scatter_tokens_quant(pages, kq, ks, vq, vs, block_tables,
                                     pos, valid_len)
    tgt, off = _token_write_targets(pages, k.shape[0], k.shape[1],
                                    block_tables, pos, valid_len)
    return {
        "k": pages["k"].at[tgt, off].set(k.astype(pages["k"].dtype),
                                         mode="drop"),
        "v": pages["v"].at[tgt, off].set(v.astype(pages["v"].dtype),
                                         mode="drop"),
    }


def _token_write_targets(pages, B, S, block_tables, pos, valid_len):
    """(tgt, off) page/offset pairs for an S-token scatter; dropped
    writes (unallocated / out-of-span / pad rows) map tgt to the OOB
    page index ``nB``."""
    nB, bs = pages["k"].shape[0], pages["k"].shape[1]
    n_blk = block_tables.shape[1]
    p = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]     # (B, S)
    blk = jnp.clip(p // bs, 0, n_blk - 1)
    off = p % bs
    phys = jnp.take_along_axis(block_tables, blk, axis=1)          # (B, S)
    ok = (phys >= 0) & (p < n_blk * bs)
    if valid_len is not None:
        ok &= jnp.arange(S, dtype=jnp.int32)[None, :] < valid_len[:, None]
    return jnp.where(ok, phys, nB), off            # nB is OOB => dropped


def _scatter_tokens_quant(pages, kq, ks, vq, vs, block_tables, pos,
                          valid_len=None):
    """Token scatter of PRE-quantized K/V (+ scales).  Callers that
    already round-tripped the suffix for attention pass the same ints
    here — re-quantizing the dequantized values would drift (the eps in
    the scale would be applied twice)."""
    tgt, off = _token_write_targets(pages, kq.shape[0], kq.shape[1],
                                    block_tables, pos, valid_len)
    return {
        "k": pages["k"].at[tgt, off].set(kq, mode="drop"),
        "v": pages["v"].at[tgt, off].set(vq, mode="drop"),
        "k_scale": pages["k_scale"].at[tgt, off].set(ks, mode="drop"),
        "v_scale": pages["v_scale"].at[tgt, off].set(vs, mode="drop"),
    }


def attention_extend_paged(cfg: ModelConfig, params, x, pos, pages,
                           block_tables, valid_len=None, *,
                           use_pallas: bool = False):
    """Multi-token decode against the paged pool: score ``S`` proposed /
    teacher-forced tokens in ONE call (speculative verify, chunked
    catch-up prefill) — the causal-suffix machinery of
    ``attention_prefill_paged`` applied at an arbitrary mid-block
    position.

    x: (B, S, d) token activations at absolute positions ``pos + i``;
    pages: this layer's pool dict; block_tables: (B, n_blk) the slot's
    FULL table (context and write span in one view).  The context is the
    PRE-WRITE gathered view masked strictly below ``pos`` — stale
    entries from a previously rejected speculation (positions >= pos)
    are invisible, which is exactly what makes KV rollback a no-op on
    pages — and the S new tokens attend each other causally as a
    suffix.  K/V for rows ``i < valid_len`` is then scattered into the
    pages at ``pos + i`` (see ``scatter_kv_tokens``; rejected proposals
    stay written but stay masked until overwritten in sequence order).
    Returns (out (B, S, d), new_pages).

    On a quantized pool the suffix attends the int8 ROUND-TRIP of its
    own K/V — the same values every later read of those pages sees —
    and ``use_pallas=True`` swaps the gather read for the fused
    dequant ``kernels.flash_attention.paged_extend_attention`` kernel
    (pages never materialise in f32; the kernel receives the already
    round-tripped suffix so both reads agree to accumulation
    tolerance).  On an f32 pool ``use_pallas`` is ignored and the path
    stays bit-exact.
    """
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    scale = cfg.attn_scale if cfg.attn_scale is not None else hd ** -0.5
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]

    q, k, v = _project_seq(cfg, params, x, positions, is_global=True)

    quant = kv_pages_quantized(pages)
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k = dequantize_kv(kq, ks, k.dtype)
        v = dequantize_kv(vq, vs, v.dtype)
        new_pages = _scatter_tokens_quant(pages, kq, ks, vq, vs,
                                          block_tables, pos, valid_len)
    else:
        new_pages = scatter_kv_tokens(pages, k, v, block_tables, pos,
                                      valid_len)

    if quant and use_pallas:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.paged_extend_attention(
            q, pages["k"], pages["v"], k, v, block_tables, pos,
            scale=scale, softcap=cfg.attn_logit_softcap,
            k_scale=pages["k_scale"], v_scale=pages["v_scale"])
        o = weight_einsum("bshq,hqd->bsd", out.astype(x.dtype),
                          params["wo"])
        return o, new_pages

    nB, bs = pages["k"].shape[0], pages["k"].shape[1]
    bt = jnp.clip(block_tables, 0, nB - 1)
    ck = pages["k"][bt].reshape(B, -1, K, hd)
    cv = pages["v"][bt].reshape(B, -1, K, hd)
    if quant:
        ck = dequantize_kv(ck, pages["k_scale"][bt].reshape(B, -1, K))
        cv = dequantize_kv(cv, pages["v_scale"][bt].reshape(B, -1, K))
    L = block_tables.shape[1] * bs
    t = jnp.arange(L, dtype=jnp.int32)
    allocated = jnp.repeat(block_tables >= 0, bs, axis=1)
    ctx_ok = allocated & (t[None, :] < pos[:, None])               # (B, L)
    mask = jnp.concatenate(
        [jnp.broadcast_to(ctx_ok[:, None, :], (B, S, L)),
         jnp.broadcast_to(causal_mask(S, S), (B, S, S))], axis=-1)
    k_all = jnp.concatenate([ck.astype(x.dtype), k], axis=1)
    v_all = jnp.concatenate([cv.astype(x.dtype), v], axis=1)
    qg = q.reshape(B, S, K, G, hd)
    out = attention_weights_and_out(qg, k_all, v_all, mask[:, None, None],
                                    scale=scale,
                                    softcap=cfg.attn_logit_softcap)
    o = weight_einsum("bshq,hqd->bsd", out.reshape(B, S, H, hd),
                      params["wo"])
    return o, new_pages


def attention_extend(cfg: ModelConfig, params, x, cache, pos, *,
                     is_global: bool, valid_len=None):
    """Multi-token decode against a DENSE cache (global strip or local
    ring) — the non-paged leg of ``extend_paged`` for families whose
    trunk mixes paged global layers with dense ring layers.

    The old entries are read PRE-write and masked strictly below
    ``pos``: sequential decode would evict ring entry ``(pos+j) % W``
    only at step ``j``, after steps ``i < j`` attended it, so a
    write-then-read over the whole chunk would lose context — reading
    the pre-write ring plus the new tokens as a causal suffix preserves
    exactly the sequential semantics (requires S <= window).  Rows
    ``i >= valid_len`` (host padding) drop their writes so pad tokens
    can never evict live ring context.
    """
    B, S, d = x.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    scale = cfg.attn_scale if cfg.attn_scale is not None else hd ** -0.5
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]

    q, knew, vnew = _project_seq(cfg, params, x, positions,
                                 is_global=is_global)

    T = cache["k"].shape[1]
    window = 0 if is_global else cfg.local_window
    slots = cache["slots"]                                         # (B, T)
    old_ok = (slots[:, None, :] >= 0) & \
        (slots[:, None, :] < pos[:, None, None])
    rel = jnp.arange(S, dtype=jnp.int32)
    new_ok = rel[None, :] <= rel[:, None]                          # (S, S)
    if window:
        old_ok &= slots[:, None, :] > (positions[:, :, None] - window)
        new_ok &= (rel[:, None] - rel[None, :]) < window
    mask = jnp.concatenate(
        [jnp.broadcast_to(old_ok, (B, S, T)),
         jnp.broadcast_to(new_ok, (B, S, S))], axis=-1)
    k_all = jnp.concatenate([cache["k"].astype(x.dtype), knew], axis=1)
    v_all = jnp.concatenate([cache["v"].astype(x.dtype), vnew], axis=1)
    qg = q.reshape(B, S, K, G, hd)
    out = attention_weights_and_out(qg, k_all, v_all, mask[:, None, None],
                                    scale=scale,
                                    softcap=cfg.attn_logit_softcap)
    o = weight_einsum("bshq,hqd->bsd", out.reshape(B, S, H, hd),
                      params["wo"])

    ring = positions % T
    ok = (jnp.arange(S, dtype=jnp.int32)[None, :] < valid_len[:, None]
          if valid_len is not None else jnp.ones((B, S), bool))
    ring_w = jnp.where(ok, ring, T)                # T is OOB => dropped
    barange = jnp.arange(B)[:, None]
    kc = cache["k"].at[barange, ring_w].set(
        knew.astype(cache["k"].dtype), mode="drop")
    vc = cache["v"].at[barange, ring_w].set(
        vnew.astype(cache["v"].dtype), mode="drop")
    new_slots = cache["slots"].at[barange, ring_w].set(positions,
                                                      mode="drop")
    return o, {"k": kc, "v": vc, "slots": new_slots}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff=None, stack=()):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d, f), stack),
        "w_up": _dense_init(k2, (d, f), stack),
        "w_down": _dense_init(k3, (f, d), stack, in_axis_size=f),
    }


def mlp(params, x, activation="silu"):
    act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
    h = act(weight_einsum("bsd,df->bsf", x, params["w_gate"])) \
        * weight_einsum("bsd,df->bsf", x, params["w_up"])
    return weight_einsum("bsf,fd->bsd", h, params["w_down"])


def init_gelu_mlp(cfg: ModelConfig, key, stack=()):
    """Whisper-style 2-matrix GELU MLP."""
    d, f = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {
        "w_in": _dense_init(k1, (d, f), stack),
        "b_in": _zeros((f,), stack),
        "w_out": _dense_init(k2, (f, d), stack, in_axis_size=f),
        "b_out": _zeros((d,), stack),
    }


def gelu_mlp(params, x):
    h = jax.nn.gelu(weight_einsum("bsd,df->bsf", x, params["w_in"])
                    + params["b_in"].astype(x.dtype))
    return weight_einsum("bsf,fd->bsd", h, params["w_out"]) \
        + params["b_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(cfg: ModelConfig, key):
    std = cfg.d_model ** -0.5  # keeps tied-unembed logits O(1)
    p = {"table": std * jax.random.normal(key, (cfg.vocab_size, cfg.d_model))}
    return p


def embed(cfg: ModelConfig, params, tokens):
    x = params["table"].astype(cfg.activation_dtype)[tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(cfg: ModelConfig, emb_params, head_params, x):
    if cfg.tie_embeddings:
        w = emb_params["table"].astype(x.dtype)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        w = head_params["w"].astype(x.dtype)
        logits = jnp.einsum("bsd,dv->bsv", x, w)
    logits = _softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits


def init_unembed(cfg: ModelConfig, key):
    if cfg.tie_embeddings:
        return {}
    return {"w": _dense_init(key, (cfg.d_model, cfg.vocab_size))}
