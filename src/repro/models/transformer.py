"""Dense decoder-only transformer trunk (gemma/phi/llama families).

Layers are stacked and executed with ``jax.lax.scan``. Architectures
with a local:global attention pattern (gemma2/3) scan over
*super-blocks*: ``pattern_period - 1`` local (sliding-window) layers
followed by one global layer; remainder local layers get their own scan.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(cfg: ModelConfig, key, stack=()) -> Params:
    norm_init, _ = L.make_norm(cfg)
    k1, k2 = jax.random.split(key)
    p = {
        "attn": L.init_attention(cfg, k1, stack),
        "mlp": L.init_mlp(cfg, k2, stack=stack),
        "ln1": norm_init(cfg.d_model, stack),
        "ln2": norm_init(cfg.d_model, stack),
    }
    if cfg.sandwich_norms:
        p["ln1_post"] = norm_init(cfg.d_model, stack)
        p["ln2_post"] = norm_init(cfg.d_model, stack)
    return p


def init_trunk(cfg: ModelConfig, key) -> Params:
    nb, rem = cfg.pattern_blocks()
    keys = jax.random.split(key, 3)
    if cfg.pattern_period <= 1:
        return {"layers": init_block(cfg, keys[0], stack=(nb,))}
    p = {
        "super": {
            "local": init_block(cfg, keys[0], stack=(nb, cfg.pattern_period - 1)),
            "global": init_block(cfg, keys[1], stack=(nb,)),
        }
    }
    if rem:
        p["rem_local"] = init_block(cfg, keys[2], stack=(rem,))
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    norm_init, _ = L.make_norm(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": L.init_embedding(cfg, k1),
        "unembed": L.init_unembed(cfg, k2),
        "trunk": init_trunk(cfg, k3),
        "final_norm": norm_init(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def block_fwd(cfg: ModelConfig, p: Params, x, positions, *, is_global,
              use_flash=False):
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, _, _ = L.attention_fwd(cfg, p["attn"], h, positions,
                              is_global=is_global, use_flash=use_flash)
    if cfg.sandwich_norms:
        a = norm(p["ln1_post"], a)
    x = x + a
    h = norm(p["ln2"], x)
    m = L.mlp(p["mlp"], h)
    if cfg.sandwich_norms:
        m = norm(p["ln2_post"], m)
    return x + m


def block_prefill(cfg: ModelConfig, p: Params, x, positions, *, is_global,
                  use_flash=False):
    """Like block_fwd but also returns (k, v) for cache construction."""
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, k, v = L.attention_fwd(cfg, p["attn"], h, positions,
                              is_global=is_global, use_flash=use_flash)
    if cfg.sandwich_norms:
        a = norm(p["ln1_post"], a)
    x = x + a
    h = norm(p["ln2"], x)
    m = L.mlp(p["mlp"], h)
    if cfg.sandwich_norms:
        m = norm(p["ln2_post"], m)
    return x + m, (k, v)


def block_decode(cfg: ModelConfig, p: Params, x, cache, pos, *, is_global):
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, new_cache = L.attention_decode(cfg, p["attn"], h, cache, pos,
                                      is_global=is_global)
    if cfg.sandwich_norms:
        a = norm(p["ln1_post"], a)
    x = x + a
    h = norm(p["ln2"], x)
    m = L.mlp(p["mlp"], h)
    if cfg.sandwich_norms:
        m = norm(p["ln2_post"], m)
    return x + m, new_cache


def block_decode_paged(cfg: ModelConfig, p: Params, x, cache, pos,
                       block_tables, use_pallas: bool = False):
    """``block_decode`` for a GLOBAL layer whose KV lives in the paged
    pool (``layers.attention_decode_paged``)."""
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, new_cache = L.attention_decode_paged(cfg, p["attn"], h, cache, pos,
                                            block_tables,
                                            use_pallas=use_pallas)
    if cfg.sandwich_norms:
        a = norm(p["ln1_post"], a)
    x = x + a
    h = norm(p["ln2"], x)
    m = L.mlp(p["mlp"], h)
    if cfg.sandwich_norms:
        m = norm(p["ln2_post"], m)
    return x + m, new_cache


def block_extend_paged(cfg: ModelConfig, p: Params, x, pos, cache,
                       block_tables, valid_len=None, *,
                       use_pallas: bool = False):
    """``block_decode_paged`` for S tokens at once — speculative verify
    / chunked catch-up (``layers.attention_extend_paged``)."""
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, new_cache = L.attention_extend_paged(cfg, p["attn"], h, pos, cache,
                                            block_tables, valid_len,
                                            use_pallas=use_pallas)
    if cfg.sandwich_norms:
        a = norm(p["ln1_post"], a)
    x = x + a
    h = norm(p["ln2"], x)
    m = L.mlp(p["mlp"], h)
    if cfg.sandwich_norms:
        m = norm(p["ln2_post"], m)
    return x + m, new_cache


def block_extend(cfg: ModelConfig, p: Params, x, cache, pos, *,
                 is_global, valid_len=None):
    """``block_decode`` for S tokens against a dense (ring/strip) cache
    (``layers.attention_extend``)."""
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, new_cache = L.attention_extend(cfg, p["attn"], h, cache, pos,
                                      is_global=is_global,
                                      valid_len=valid_len)
    if cfg.sandwich_norms:
        a = norm(p["ln1_post"], a)
    x = x + a
    h = norm(p["ln2"], x)
    m = L.mlp(p["mlp"], h)
    if cfg.sandwich_norms:
        m = norm(p["ln2_post"], m)
    return x + m, new_cache


def block_prefill_paged(cfg: ModelConfig, p: Params, x, positions, pages,
                        write_tables, ctx_tables=None, ctx_len=None, *,
                        use_flash=False):
    """``block_prefill`` for a GLOBAL layer writing K/V straight into
    its page pool (and, on a prefix-cache hit, attending the shared
    prefix pages) — see ``layers.attention_prefill_paged``."""
    _, norm = L.make_norm(cfg)
    h = norm(p["ln1"], x)
    a, new_pages = L.attention_prefill_paged(
        cfg, p["attn"], h, positions, pages, write_tables, ctx_tables,
        ctx_len, use_flash=use_flash)
    if cfg.sandwich_norms:
        a = norm(p["ln1_post"], a)
    x = x + a
    h = norm(p["ln2"], x)
    m = L.mlp(p["mlp"], h)
    if cfg.sandwich_norms:
        m = norm(p["ln2_post"], m)
    return x + m, new_pages


def _maybe_remat(fn, policy: Optional[str]):
    if not policy or policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    pol = getattr(jax.checkpoint_policies, policy)
    return jax.checkpoint(fn, policy=pol)


def _uniform_layers(cfg: ModelConfig, trunk: Params):
    """The stacked-layer pytree the uniform (``pattern_period <= 1``)
    scan runs over.  ``lax.scan`` takes its trip count from the leaf
    axis-0 extent, so a params tree holding MORE stacked layers than
    ``cfg.num_layers`` is sliced here, in-trace — which is what lets an
    early-exit self-draft (``serving.spec_decode.make_self_draft``)
    share the verify model's full trunk buffer by reference instead of
    materialising a device copy of its first half."""
    layers = trunk["layers"]
    if jax.tree.leaves(layers)[0].shape[0] == cfg.num_layers:
        return layers
    return jax.tree.map(lambda a: a[:cfg.num_layers], layers)


# ---------------------------------------------------------------------------
# trunk forward (train / prefill without cache)
# ---------------------------------------------------------------------------

def trunk_fwd(cfg: ModelConfig, trunk: Params, x, positions, *,
              use_flash=False, remat: Optional[str] = None):
    if cfg.pattern_period <= 1:
        def body(h, lp):
            return block_fwd(cfg, lp, h, positions, is_global=True,
                             use_flash=use_flash), None
        body = _maybe_remat(body, remat)
        x, _ = lax.scan(body, x, _uniform_layers(cfg, trunk))
        return x

    def local_body(h, lp):
        return block_fwd(cfg, lp, h, positions, is_global=False,
                         use_flash=use_flash), None

    def super_body(h, sp):
        h, _ = lax.scan(_maybe_remat(local_body, remat), h, sp["local"])
        h = block_fwd(cfg, sp["global"], h, positions, is_global=True,
                      use_flash=use_flash)
        return h, None

    x, _ = lax.scan(_maybe_remat(super_body, remat), x, trunk["super"])
    if "rem_local" in trunk:
        x, _ = lax.scan(_maybe_remat(local_body, remat), x, trunk["rem_local"])
    return x


def forward(cfg: ModelConfig, params: Params, tokens, *,
            prefix_embeds=None, use_flash=False, remat=None):
    """Full-sequence logits. tokens: (B, S_text).

    prefix_embeds: optional (B, P, d) embeddings prepended (VLM image
    tokens); logits are returned for the full sequence.
    """
    x = L.embed(cfg, params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = trunk_fwd(cfg, params["trunk"], x, positions,
                  use_flash=use_flash, remat=remat)
    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    return L.unembed(cfg, params["embed"], params["unembed"], x)


# ---------------------------------------------------------------------------
# cache layout + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    nb, rem = cfg.pattern_blocks()
    if cfg.pattern_period <= 1:
        return {"layers": L.init_kv_cache(cfg, batch, max_len, stack=(nb,))}
    W = min(cfg.local_window, max_len)
    c = {
        "super": {
            "local": L.init_kv_cache(cfg, batch, W,
                                     stack=(nb, cfg.pattern_period - 1)),
            "global": L.init_kv_cache(cfg, batch, max_len, stack=(nb,)),
        }
    }
    if rem:
        c["rem_local"] = L.init_kv_cache(cfg, batch, W, stack=(rem,))
    return c


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     num_blocks: int, block_size: int,
                     kv_dtype=None) -> Params:
    """Like ``init_cache`` but GLOBAL layers get a shared page pool
    (no batch axis) instead of per-slot ``max_len`` strips; local
    ring-window layers stay dense at W.  ``kv_dtype="int8"`` makes the
    pool quantized (scale leaves ride along; dense ring caches stay
    f32 — they are per-slot, not pool capacity)."""
    quant = kv_dtype == "int8"
    nb, rem = cfg.pattern_blocks()
    if cfg.pattern_period <= 1:
        return {"layers": L.init_kv_pages(cfg, num_blocks, block_size,
                                          stack=(nb,), quant=quant)}
    W = min(cfg.local_window, max_len)
    c = {
        "super": {
            "local": L.init_kv_cache(cfg, batch, W,
                                     stack=(nb, cfg.pattern_period - 1)),
            "global": L.init_kv_pages(cfg, num_blocks, block_size,
                                      stack=(nb,), quant=quant),
        }
    }
    if rem:
        c["rem_local"] = L.init_kv_cache(cfg, batch, W, stack=(rem,))
    return c


def trunk_decode(cfg: ModelConfig, trunk: Params, cache: Params, x, pos):
    """x: (B, 1, d); pos: scalar int32. Returns (x, new_cache)."""
    if cfg.pattern_period <= 1:
        def body(h, inp):
            lp, c = inp
            h, c2 = block_decode(cfg, lp, h, c, pos, is_global=True)
            return h, c2
        x, new_c = lax.scan(body, x, (_uniform_layers(cfg, trunk),
                                      cache["layers"]))
        return x, {"layers": new_c}

    def local_body(h, inp):
        lp, c = inp
        h, c2 = block_decode(cfg, lp, h, c, pos, is_global=False)
        return h, c2

    def super_body(h, inp):
        sp, sc = inp
        h, lc = lax.scan(local_body, h, (sp["local"], sc["local"]))
        h, gc = block_decode(cfg, sp["global"], h, sc["global"], pos,
                             is_global=True)
        return h, {"local": lc, "global": gc}

    x, new_super = lax.scan(super_body, x, (trunk["super"], cache["super"]))
    new_cache = {"super": new_super}
    if "rem_local" in trunk:
        x, rc = lax.scan(local_body, x, (trunk["rem_local"], cache["rem_local"]))
        new_cache["rem_local"] = rc
    return x, new_cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params, tokens, pos):
    """tokens: (B, 1) int32; pos: scalar int32 — position being written."""
    x = L.embed(cfg, params["embed"], tokens)
    x, new_cache = trunk_decode(cfg, params["trunk"], cache, x, pos)
    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache


def trunk_decode_paged(cfg: ModelConfig, trunk: Params, cache: Params, x,
                       pos, block_tables, use_pallas: bool = False):
    """``trunk_decode`` against ``init_paged_cache``: global layers read
    and write KV pages via the (B, n_blk) block table; local ring layers
    are unchanged."""
    if cfg.pattern_period <= 1:
        def body(h, inp):
            lp, c = inp
            h, c2 = block_decode_paged(cfg, lp, h, c, pos, block_tables,
                                       use_pallas)
            return h, c2
        x, new_c = lax.scan(body, x, (_uniform_layers(cfg, trunk),
                                      cache["layers"]))
        return x, {"layers": new_c}

    def local_body(h, inp):
        lp, c = inp
        h, c2 = block_decode(cfg, lp, h, c, pos, is_global=False)
        return h, c2

    def super_body(h, inp):
        sp, sc = inp
        h, lc = lax.scan(local_body, h, (sp["local"], sc["local"]))
        h, gc = block_decode_paged(cfg, sp["global"], h, sc["global"], pos,
                                   block_tables, use_pallas)
        return h, {"local": lc, "global": gc}

    x, new_super = lax.scan(super_body, x, (trunk["super"], cache["super"]))
    new_cache = {"super": new_super}
    if "rem_local" in trunk:
        x, rc = lax.scan(local_body, x, (trunk["rem_local"], cache["rem_local"]))
        new_cache["rem_local"] = rc
    return x, new_cache


def decode_step_paged(cfg: ModelConfig, params: Params, cache: Params,
                      tokens, pos, block_tables, use_pallas: bool = False):
    """Paged twin of ``decode_step``; ``block_tables``: (B, n_blk) int32."""
    x = L.embed(cfg, params["embed"], tokens)
    x, new_cache = trunk_decode_paged(cfg, params["trunk"], cache, x, pos,
                                      block_tables, use_pallas)
    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache


def extend_paged(cfg: ModelConfig, params: Params, cache: Params, tokens,
                 pos, block_tables, valid_len=None,
                 use_pallas: bool = False):
    """Score S tokens against the paged cache in ONE jitted call.

    tokens: (B, S) int32 at absolute positions ``pos + i`` (pos: (B,)
    int32 per-slot write frontier).  Global layers extend through their
    page pool (``layers.attention_extend_paged``); local ring layers
    (gemma patterns) extend their dense window with the same pre-write
    causal-suffix semantics (``layers.attention_extend``; requires
    S <= local_window).  Returns (logits (B, S, V), new_cache) — row i
    is the next-token distribution AFTER consuming ``tokens[:, :i+1]``,
    which is what speculative verify and multi-token catch-up prefill
    consume.  Rows ``i >= valid_len`` are padding: their logits are
    garbage and their K/V writes are dropped.
    """
    x = L.embed(cfg, params["embed"], tokens)
    trunk = params["trunk"]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (x.shape[0],))

    if cfg.pattern_period <= 1:
        def body(h, inp):
            lp, c = inp
            h, c2 = block_extend_paged(cfg, lp, h, pos, c, block_tables,
                                       valid_len, use_pallas=use_pallas)
            return h, c2
        x, new_c = lax.scan(body, x, (_uniform_layers(cfg, trunk),
                                      cache["layers"]))
        new_cache = {"layers": new_c}
    else:
        def local_body(h, inp):
            lp, c = inp
            h, c2 = block_extend(cfg, lp, h, c, pos, is_global=False,
                                 valid_len=valid_len)
            return h, c2

        def super_body(h, inp):
            sp, sc = inp
            h, lc = lax.scan(local_body, h, (sp["local"], sc["local"]))
            h, gc = block_extend_paged(cfg, sp["global"], h, pos,
                                       sc["global"], block_tables,
                                       valid_len, use_pallas=use_pallas)
            return h, {"local": lc, "global": gc}

        x, new_super = lax.scan(super_body, x,
                                (trunk["super"], cache["super"]))
        new_cache = {"super": new_super}
        if "rem_local" in trunk:
            x, rc = lax.scan(local_body, x,
                             (trunk["rem_local"], cache["rem_local"]))
            new_cache["rem_local"] = rc

    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache


def extend(cfg: ModelConfig, params: Params, cache: Params, tokens, pos,
           valid_len=None):
    """Dense twin of ``extend_paged``: score S tokens against the dense
    strip/ring caches (``ServeConfig.paged=False`` A/B path).  Same row
    semantics; bit-identical to the paged extend on the same logical
    state."""
    x = L.embed(cfg, params["embed"], tokens)
    trunk = params["trunk"]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (x.shape[0],))

    def make_body(is_global):
        def body(h, inp):
            lp, c = inp
            h, c2 = block_extend(cfg, lp, h, c, pos, is_global=is_global,
                                 valid_len=valid_len)
            return h, c2
        return body

    if cfg.pattern_period <= 1:
        x, new_c = lax.scan(make_body(True), x,
                            (_uniform_layers(cfg, trunk),
                             cache["layers"]))
        new_cache = {"layers": new_c}
    else:
        def super_body(h, inp):
            sp, sc = inp
            h, lc = lax.scan(make_body(False), h, (sp["local"],
                                                   sc["local"]))
            h, gc = block_extend(cfg, sp["global"], h, sc["global"], pos,
                                 is_global=True, valid_len=valid_len)
            return h, {"local": lc, "global": gc}

        x, new_super = lax.scan(super_body, x,
                                (trunk["super"], cache["super"]))
        new_cache = {"super": new_super}
        if "rem_local" in trunk:
            x, rc = lax.scan(make_body(False), x,
                             (trunk["rem_local"], cache["rem_local"]))
            new_cache["rem_local"] = rc

    _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill: forward + cache construction
# ---------------------------------------------------------------------------

def broadcast_true_len(true_len, batch: int):
    """``true_len`` (int | (B,) int32 | None) -> (B,) int32 | None."""
    if true_len is None:
        return None
    return jnp.broadcast_to(jnp.asarray(true_len, jnp.int32), (batch,))


def gather_last(x, n):
    """x: (B, S, d); n: (B,) true lengths -> (B, 1, d) at index n-1."""
    idx = jnp.maximum(n - 1, 0).astype(jnp.int32)[:, None, None]
    return jnp.take_along_axis(x, idx, axis=1)


def _fill_global(cfg, batch, max_len, k, v, n=None):
    """Dense decode cache from prefill K/V.

    ``n``: optional (B,) true sequence lengths — positions >= n are
    right-padding whose K/V must never be attended: their ``slots``
    entries are set to -1 (invalid), which masks them in
    ``attention_decode`` until the decode loop overwrites them in
    sequence order.
    """
    S = k.shape[1]
    cache = L.init_kv_cache(cfg, batch, max_len, dtype=k.dtype)
    cache["k"] = lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
    cache["v"] = lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
    pos = jnp.arange(max_len, dtype=jnp.int32)
    if n is None:
        slots = jnp.broadcast_to(jnp.where(pos < S, pos, -1),
                                 (batch, max_len))
    else:
        slots = jnp.where(pos[None, :] < n[:, None], pos[None, :], -1)
        slots = jnp.broadcast_to(slots, (batch, max_len))
    cache["slots"] = slots.astype(jnp.int32)
    return cache


def _fill_local(cfg, batch, max_len, k, v, n=None):
    """Sliding-window ring cache from prefill K/V.

    With ``n`` given, the ring holds positions [n-W, n) of each row —
    padded positions must not evict true context (a right-padded row
    whose pads landed in the ring would decode with an empty window).
    """
    S = k.shape[1]
    W = min(cfg.local_window, max_len)
    cache = L.init_kv_cache(cfg, batch, W, dtype=k.dtype)
    if n is not None:
        # ring slot j holds the largest position p <= n-1 with p % W == j
        j = jnp.arange(W, dtype=jnp.int32)
        p = j[None, :] + ((n[:, None] - 1 - j[None, :]) // W) * W  # (B, W)
        valid = (p >= 0) & (p < n[:, None])
        idx = jnp.clip(p, 0, S - 1)
        take = lambda src: jnp.take_along_axis(
            src, idx[..., None, None], axis=1)
        cache["k"] = jnp.where(valid[..., None, None], take(k), 0)
        cache["v"] = jnp.where(valid[..., None, None], take(v), 0)
        cache["slots"] = jnp.where(valid, p, -1)
        return cache
    if S >= W:
        pos = jnp.arange(S - W, S)
        idx = pos % W
        cache["k"] = cache["k"].at[:, idx].set(k[:, S - W:])
        cache["v"] = cache["v"].at[:, idx].set(v[:, S - W:])
        cache["slots"] = cache["slots"].at[:, idx].set(
            pos.astype(jnp.int32)[None])
    else:
        cache["k"] = lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
        cache["v"] = lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
        cache["slots"] = cache["slots"].at[:, :S].set(
            jnp.arange(S, dtype=jnp.int32)[None])
    return cache


def prefill(cfg: ModelConfig, params: Params, tokens, max_len, *,
            prefix_embeds=None, use_flash=False, true_len=None):
    """Run the prompt, return (last-token logits, cache sized max_len).

    ``true_len``: optional int | (B,) int32 — true TEXT token count per
    row when ``tokens`` is right-padded to a prefill bucket.  Logits are
    then taken at each row's true last token (offset by the prefix
    length for VLM image tokens), and pad positions are marked invalid
    in the caches, so padded prefill is EXACT, not approximate.
    """
    x = L.embed(cfg, params["embed"], tokens)
    P = 0
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    n = broadcast_true_len(true_len, B)
    n_full = None if n is None else n + P
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    trunk = params["trunk"]

    if cfg.pattern_period <= 1:
        def body(h, lp):
            h, kv = block_prefill(cfg, lp, h, positions, is_global=True,
                                  use_flash=use_flash)
            return h, kv
        x, (ks, vs) = lax.scan(body, x, _uniform_layers(cfg, trunk))
        cache = {"layers": jax.vmap(
            lambda k, v: _fill_global(cfg, B, max_len, k, v, n_full))(ks, vs)}
    else:
        def local_body(h, lp):
            h, kv = block_prefill(cfg, lp, h, positions, is_global=False,
                                  use_flash=use_flash)
            return h, kv

        def super_body(h, sp):
            h, lkv = lax.scan(local_body, h, sp["local"])
            h, gkv = block_prefill(cfg, sp["global"], h, positions,
                                   is_global=True, use_flash=use_flash)
            return h, (lkv, gkv)

        x, ((lks, lvs), (gks, gvs)) = lax.scan(super_body, x, trunk["super"])
        fill_l = jax.vmap(jax.vmap(
            lambda k, v: _fill_local(cfg, B, max_len, k, v, n_full)))
        fill_g = jax.vmap(
            lambda k, v: _fill_global(cfg, B, max_len, k, v, n_full))
        cache = {"super": {"local": fill_l(lks, lvs),
                           "global": fill_g(gks, gvs)}}
        if "rem_local" in trunk:
            x, (rks, rvs) = lax.scan(local_body, x, trunk["rem_local"])
            cache["rem_local"] = jax.vmap(
                lambda k, v: _fill_local(cfg, B, max_len, k, v, n_full))(
                    rks, rvs)

    _, norm = L.make_norm(cfg)
    x = x[:, -1:] if n_full is None else gather_last(x, n_full)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, cache


# ---------------------------------------------------------------------------
# paged prefill: admission writes straight into the engine cache
# ---------------------------------------------------------------------------

def scatter_cache_rows(full, rows, slots, axis: int):
    """Scatter an ``m``-row cache subtree into the batched engine cache
    at ``slots`` (every leaf shares the same batch ``axis``)."""
    return jax.tree.map(
        lambda f, r: L.scatter_rows(f, r, slots, axis), full, rows)


def prefill_paged(cfg: ModelConfig, params: Params, tokens, max_len,
                  cache, *, slots, write_tables=None, ctx_tables=None,
                  ctx_len=None, true_len=None, prefix_embeds=None,
                  use_flash=False):
    """Admission prefill fused with cache insertion: runs ``m`` prompt
    rows and writes their decode state DIRECTLY into the engine's
    batched cache — global-layer K/V lands in the shared page pool via
    ``write_tables`` (no dense strip is ever materialised and shadow-
    copied), local ring layers land in their dense rows at ``slots``.

    ``ctx_tables``/``ctx_len`` carry a radix prefix-cache hit: the rows
    are then the UNMATCHED SUFFIX only, positioned at ``ctx_len +
    arange(S)``, and global attention additionally reads the shared
    prefix's pages — the hit skips the prefix's prefill FLOPs entirely.
    Context is only ever passed for fully-paged configs
    (``pattern_period <= 1``); local-ring state cannot be reconstructed
    from pages (see ``model.prefix_sharable``).

    With ``write_tables=None`` this is the dense engine's admission:
    the same prefill math, scattered into per-slot ``max_len`` strips.
    Returns (per-row last-true-token logits, updated engine cache).
    """
    x = L.embed(cfg, params["embed"], tokens)
    P = 0
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    n = broadcast_true_len(true_len, B)
    n_full = None if n is None else n + P
    off = (jnp.zeros((B,), jnp.int32) if ctx_len is None
           else jnp.asarray(ctx_len, jnp.int32))
    positions = off[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    paged = write_tables is not None
    if ctx_tables is not None and cfg.pattern_period > 1:
        raise ValueError("prefix-cache context requires a fully-paged "
                         "trunk (pattern_period <= 1)")
    trunk = params["trunk"]
    slots = jnp.asarray(slots, jnp.int32)
    new_cache = dict(cache)

    if cfg.pattern_period <= 1:
        if paged:
            def body(h, inp):
                lp, pg = inp
                h, pg2 = block_prefill_paged(
                    cfg, lp, h, positions, pg, write_tables, ctx_tables,
                    ctx_len, use_flash=use_flash)
                return h, pg2
            x, pages = lax.scan(body, x, (_uniform_layers(cfg, trunk),
                                          cache["layers"]))
            new_cache["layers"] = pages
        else:
            def body(h, lp):
                h, kv = block_prefill(cfg, lp, h, positions,
                                      is_global=True, use_flash=use_flash)
                return h, kv
            x, (ks, vs) = lax.scan(body, x, _uniform_layers(cfg, trunk))
            rows = jax.vmap(lambda k, v: _fill_global(
                cfg, B, max_len, k, v, n_full))(ks, vs)
            new_cache["layers"] = scatter_cache_rows(
                cache["layers"], rows, slots, 1)
    else:
        def local_body(h, lp):
            h, kv = block_prefill(cfg, lp, h, positions, is_global=False,
                                  use_flash=use_flash)
            return h, kv

        if paged:
            def super_body(h, inp):
                sp, pg = inp
                h, lkv = lax.scan(local_body, h, sp["local"])
                h, g = block_prefill_paged(cfg, sp["global"], h, positions,
                                           pg, write_tables,
                                           use_flash=use_flash)
                return h, (lkv, g)
            x, ((lks, lvs), gout) = lax.scan(
                super_body, x, (trunk["super"], cache["super"]["global"]))
        else:
            def super_body(h, sp):
                h, lkv = lax.scan(local_body, h, sp["local"])
                h, g = block_prefill(cfg, sp["global"], h, positions,
                                     is_global=True, use_flash=use_flash)
                return h, (lkv, g)
            x, ((lks, lvs), gout) = lax.scan(super_body, x, trunk["super"])
        fill_l = jax.vmap(jax.vmap(
            lambda k, v: _fill_local(cfg, B, max_len, k, v, n_full)))
        lrows = fill_l(lks, lvs)
        new_super = {
            "local": scatter_cache_rows(cache["super"]["local"], lrows,
                                        slots, 2),
        }
        if paged:
            new_super["global"] = gout
        else:
            gks, gvs = gout
            grows = jax.vmap(lambda k, v: _fill_global(
                cfg, B, max_len, k, v, n_full))(gks, gvs)
            new_super["global"] = scatter_cache_rows(
                cache["super"]["global"], grows, slots, 1)
        new_cache["super"] = new_super
        if "rem_local" in trunk:
            x, (rks, rvs) = lax.scan(local_body, x, trunk["rem_local"])
            rrows = jax.vmap(lambda k, v: _fill_local(
                cfg, B, max_len, k, v, n_full))(rks, rvs)
            new_cache["rem_local"] = scatter_cache_rows(
                cache["rem_local"], rrows, slots, 1)

    _, norm = L.make_norm(cfg)
    x = x[:, -1:] if n_full is None else gather_last(x, n_full)
    x = norm(params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], params["unembed"], x)
    return logits, new_cache
