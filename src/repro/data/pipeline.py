"""Synthetic data pipeline: deterministic, shardable, learnable.

The consumer-edge setting has no shared public corpus (data never leave
the trust zone — DESIGN.md §Privacy), so the framework ships a synthetic
generator with a *learnable* structure: tokens follow a fixed random
bigram chain, giving cross-entropy strictly below ln(V) once a model
learns the transitions.  The loader shards the global batch over hosts
by slicing a counter-based PRNG stream — no coordination needed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as M


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    branching: int = 4   # out-degree of the bigram chain (entropy = ln b)
    shard_index: int = 0
    num_shards: int = 1


def _bigram_table(cfg: DataConfig, vocab: int) -> np.ndarray:
    """vocab x branching successor table (deterministic in seed)."""
    rng = np.random.default_rng(cfg.seed)
    return rng.integers(0, vocab, size=(vocab, cfg.branching))


def synthetic_tokens(dcfg: DataConfig, vocab: int, batch: int, seq: int,
                     step: int) -> np.ndarray:
    """(batch, seq+1) int32 bigram-chain tokens for a global step."""
    table = _bigram_table(dcfg, vocab)
    rng = np.random.default_rng(
        (dcfg.seed, step, dcfg.shard_index, 0xEDE_A1))
    out = np.empty((batch, seq + 1), np.int32)
    out[:, 0] = rng.integers(0, vocab, size=batch)
    choices = rng.integers(0, dcfg.branching, size=(batch, seq))
    for t in range(seq):
        out[:, t + 1] = table[out[:, t], choices[:, t]]
    return out


def data_iterator(cfg: ModelConfig, shape: InputShape,
                  dcfg: Optional[DataConfig] = None) -> Iterator[dict]:
    """Yields model batches; embeddings inputs (stub frontends) are
    generated as deterministic pseudo-random floats."""
    dcfg = dcfg or DataConfig()
    shapes = M.batch_shapes(cfg, shape)
    local_b = shape.global_batch // dcfg.num_shards
    step = 0
    while True:
        batch = {}
        tok_shape = shapes["tokens"].shape
        toks = synthetic_tokens(dcfg, cfg.vocab_size, local_b,
                                tok_shape[1], step)
        batch["tokens"] = jnp.asarray(toks[:, :-1])
        batch["targets"] = jnp.asarray(toks[:, 1:])
        for name in ("image_embeds", "audio_embeds"):
            if name in shapes:
                sds = shapes[name]
                key = jax.random.PRNGKey(
                    (dcfg.seed * 1000003 + step) % (2 ** 31))
                batch[name] = 0.1 * jax.random.normal(
                    key, (local_b,) + sds.shape[1:], sds.dtype)
        yield batch
        step += 1
