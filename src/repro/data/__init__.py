from repro.data.pipeline import DataConfig, data_iterator, synthetic_tokens

__all__ = ["DataConfig", "data_iterator", "synthetic_tokens"]
