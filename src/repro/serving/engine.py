"""Multi-tenant serving engine with continuous batching over a paged KV
cache.

The EdgeAI-Hub's inference runtime: fixed-slot batched decode with
per-slot positions (the per-sequence ``pos`` vector threads through
``attention_decode``), batched bucketed admission, and eviction on
EOS / length / preemption.  The hub's scheduler policy
(``core.scheduler.admission_rank``) decides WHO is admitted next; this
module executes it.

Paged KV (block-table decode contract)
--------------------------------------
GLOBAL attention layers no longer own a dense ``max_len`` strip per
slot.  Their K/V lives in a shared pool of ``kv_block_size``-token
pages (``models.layers.init_kv_pages``, allocated by
``kv_pool.KVBlockPool``); each slot holds an ordered list of physical
page ids whose device mirror is the ``(max_slots, max_len //
kv_block_size)`` int32 ``block_tables`` array passed to
``model.decode_step_paged`` every step (-1 = unallocated).  The engine
maintains these invariants:

* before a decode wave, every active slot's table covers its write
  position ``pos`` (``_ensure_blocks`` appends a page on boundary
  crossing; on pool exhaustion the slot is preempted back to the queue
  with its pages detached — "preempt-or-queue");
* admission is capacity-aware: a request is admitted only when enough
  FREE POOL BLOCKS exist for its prompt (+1 decode write), not merely
  when a slot is free;
* ``_finish`` releases the slot's pages; ``preempt`` detaches them onto
  ``Request.saved_state`` so resume is still re-prefill-free;
* the logical view ``n_blk * kv_block_size == max_len`` makes paged
  decode bit-for-bit identical to the dense path — only HBM residency
  shrinks, from ``max_slots x max_len`` strips to tokens actually in
  flight.

Local ring-window layers stay dense at ``W`` and SSM state is O(1), so
families with no global KV layers (ssm, hybrid) transparently run the
dense path with zero pool demand.

Admission semantics (exact, see ``model.prefill(true_len=...)``)
----------------------------------------------------------------
* Prompts are right-padded to the smallest prefill bucket that fits and
  prefilled in one batch per bucket.  ``true_len`` makes the padding
  semantically invisible: admission logits are taken at the true last
  prompt token and pad positions never enter the decode state, so a
  5-token prompt in a 16-token bucket decodes bit-identically to an
  unpadded run.  Slot position starts at ``prefix + true_len`` (prefix =
  VLM image tokens), NOT at the bucket size.  (MoE caveat: expert
  capacity is computed from the static padded/batched shape, so token
  DROPPING under capacity pressure can differ from an unpadded run —
  see ``serving/__init__`` and ``moe._moe_tokens``.)
* Prompts longer than the largest bucket are chunked: the first
  ``max(prefill_buckets)`` tokens go through bucketed prefill, the rest
  catch up through the shared batched decode wave (one prompt token per
  step, teacher-forced, sampled outputs discarded until the prompt is
  consumed).  Catch-up requests ride the same decode batch as running
  requests, so long-prompt admission never stalls other tenants.
* Preemption (``preempt``) extracts the slot's dense cache leaves and
  decode position onto the request and detaches its KV pages;
  re-admission reinserts them directly — no re-prefill, no page copies,
  no lost context.
* ``submit`` validates resumed requests too: a saved state with no room
  left to generate (``pos + pending >= max_len - 1``) or nothing left
  to generate is rejected instead of burning a slot.
* Sampling is per-request: ``Request.temperature`` / ``Request.top_k``
  override the engine-wide defaults inside the jitted decode step.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.kv_pool import KVBlockPool, PoolExhausted, \
    blocks_for_tokens

# NOTE: repro.core.scheduler is imported lazily in _rank —
# core/__init__ pulls in hub.py, which imports this module back.

Params = Any

# Batch-axis discovery probes: the cache is shape-evaluated at TWO
# distinct batch sizes and the batch axis is the (unique) axis whose
# extent changed.  This cannot collide with any other cache dimension —
# the previous single-sentinel scheme (`shape.index(7777)`) silently
# picked the wrong axis whenever max_len/vocab/d_model happened to
# equal the sentinel.
_PROBE_A, _PROBE_B = 3, 5


def _diff_axis(a, b) -> int:
    """Axis where the two probe shapes differ; -1 when none does (a
    batchless shared-pool leaf)."""
    diffs = [i for i, (p, q) in enumerate(zip(a.shape, b.shape)) if p != q]
    if not diffs:
        return -1
    if len(diffs) > 1:
        raise ValueError(
            f"ambiguous batch axis: shapes {a.shape} / {b.shape} differ "
            f"on {diffs}")
    return diffs[0]


def cache_batch_axes(cfg: ModelConfig, max_len: int):
    """Pytree of ints: which axis of each cache leaf is the batch axis.

    Discovered structurally by shape-evaluating the cache at two batch
    sizes — no per-family bookkeeping, no sentinel collisions.
    """
    s1 = jax.eval_shape(partial(M.init_cache, cfg, _PROBE_A, max_len))
    s2 = jax.eval_shape(partial(M.init_cache, cfg, _PROBE_B, max_len))
    return jax.tree.map(_diff_axis, s1, s2)


def paged_cache_axes(cfg: ModelConfig, max_len: int, num_blocks: int,
                     block_size: int):
    """Like ``cache_batch_axes`` for the paged cache: shared page-pool
    leaves have no batch axis and map to -1."""
    s1 = jax.eval_shape(partial(M.init_paged_cache, cfg, _PROBE_A, max_len,
                                num_blocks, block_size))
    s2 = jax.eval_shape(partial(M.init_paged_cache, cfg, _PROBE_B, max_len,
                                num_blocks, block_size))
    return jax.tree.map(_diff_axis, s1, s2)


def insert_slot(cache, one, slot: int, axes):
    """Insert a batch=1 cache ``one`` into batched ``cache`` at ``slot``.
    Pool leaves (axis -1) are left untouched — their content lives in
    shared pages addressed by block tables, not per-slot strips."""
    return jax.tree.map(
        lambda full, single, ax: full if ax < 0 else
        jax.lax.dynamic_update_slice_in_dim(
            full, single.astype(full.dtype), slot, axis=ax),
        cache, one, axes)


def extract_slot(cache, slot: int, axes):
    """Slice a batch=1 cache out of batched ``cache`` at ``slot``
    (inverse of ``insert_slot`` — KV-preserving preemption).  Pool
    leaves yield an empty placeholder; their pages are detached via the
    block table instead of copied."""
    return jax.tree.map(
        lambda full, ax: jnp.zeros((0,), full.dtype) if ax < 0 else
        jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=ax),
        cache, axes)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    priority: int = 0                   # higher = more urgent (QoE)
    deadline: Optional[float] = None    # for the "edf" admission policy
    temperature: Optional[float] = None  # None -> ServeConfig.temperature
    top_k: Optional[int] = None          # None -> ServeConfig.top_k
    extras: dict = field(default_factory=dict)  # image/audio embeds
    # filled by the engine:
    generated: list = field(default_factory=list)
    done: bool = False
    arrival: Optional[float] = None     # submission stamp (engine-set)
    saved_state: Optional[dict] = None  # KV snapshot from preemption


@dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 4
    max_len: int = 256
    temperature: float = 0.0            # 0 => greedy
    top_k: int = 0                      # 0 disables top-k filtering
    eos_id: int = -1                    # -1 disables EOS stopping
    prefill_buckets: tuple = (16, 32, 64, 128)
    policy: str = "priority"            # fifo | priority | edf (QoE)
    seed: int = 0
    # paged KV pool (tokens-in-flight memory ceiling instead of
    # max_slots * max_len strips); paged=False restores dense strips
    paged: bool = True
    kv_block_size: int = 16
    kv_pool_blocks: Optional[int] = None  # None -> max_slots*max_len/bs


class EdgeServingEngine:
    """Continuous-batching decode engine for one model on one device/mesh."""

    def __init__(self, cfg: ModelConfig, params: Params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        B, T = scfg.max_slots, scfg.max_len
        bs = scfg.kv_block_size
        self.paged = bool(scfg.paged)
        if self.paged:
            if bs < 1:
                raise ValueError(f"kv_block_size must be >= 1, got {bs}")
            # the logical page view must tile max_len exactly (that is
            # what makes paged == dense bit-for-bit); shrink the block
            # size until it divides rather than reject the config
            while T % bs:
                bs //= 2
            self.n_blk = T // bs
            if scfg.kv_pool_blocks:
                # a user-set pool is a TOKEN budget: if the block size
                # shrank, keep blocks x block_size constant instead of
                # silently shrinking the budget by the same factor
                n_pool = scfg.kv_pool_blocks * scfg.kv_block_size // bs
            else:
                n_pool = B * self.n_blk
            axes = paged_cache_axes(cfg, T, n_pool, bs)
            # families with no global KV layers (ssm, hybrid ring) have
            # zero pool demand — run them on the dense path outright
            self.paged = any(a < 0 for a in jax.tree.leaves(axes))
        self.block_size = bs              # effective page size
        if self.paged:
            self.axes = axes
            self.pool = KVBlockPool(n_pool, bs)
            self.cache = M.init_paged_cache(cfg, B, T, n_pool, bs)
            self.block_tables = np.full((B, self.n_blk), -1, np.int32)
            self.slot_blocks: list[list[int]] = [[] for _ in range(B)]
        else:
            self.pool = None
            self.cache = M.init_cache(cfg, B, T)
            self.axes = cache_batch_axes(cfg, T)
        # batch axes of the DENSE prefill cache (row extraction source)
        self._dense_axes = (cache_batch_axes(cfg, T) if self.paged
                            else self.axes)
        self.tokens = np.zeros((B, 1), np.int32)
        self.pos = np.zeros((B,), np.int32)
        self.temps = np.zeros((B,), np.float32)
        self.topks = np.zeros((B,), np.int32)
        self.active = np.zeros((B,), bool)
        self.slot_req: list[Optional[Request]] = [None] * B
        self.pending: list[Optional[np.ndarray]] = [None] * B
        self.queue: list[Request] = []
        self._key = jax.random.PRNGKey(scfg.seed)
        self._rng = np.random.default_rng(scfg.seed)   # admission sampling
        self._arrival = itertools.count()
        # specialized on the static any_topk flag: the all-greedy /
        # temperature-only path must not pay an O(B·V log V) vocab sort
        # per decoded token (at most two variants ever compile)
        self._decode = jax.jit(self._decode_fn,
                               static_argnames=("any_topk",))
        self._prefills: dict[tuple, Callable] = {}
        self.steps = 0
        self.completed: list[Request] = []
        # observability: paged-admission effectiveness + pressure events
        self.peak_active = 0
        self.peak_pool_used = 0
        self.exhaust_preempts = 0
        self.reclaims = 0

    @property
    def _prefix(self) -> int:
        return self.cfg.num_image_tokens if self.cfg.family == "vlm" else 0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def reset_rng(self) -> None:
        """Re-seed the sampling state (device PRNG key + admission rng)
        to the ServeConfig seed.  Benchmarks call this after a warmup
        pass so a temperature>0 measured run samples the same tokens a
        cold engine would — replay determinism."""
        self._key = jax.random.PRNGKey(self.scfg.seed)
        self._rng = np.random.default_rng(self.scfg.seed)

    def submit(self, req: Request) -> None:
        limit = self.scfg.max_len - 1 - self._prefix
        if req.saved_state is None:
            if len(req.prompt) > limit:
                raise ValueError(
                    f"prompt length {len(req.prompt)} exceeds max_len "
                    f"budget {limit} (max_len={self.scfg.max_len})")
            worst = self._prefix + len(req.prompt) + req.max_new_tokens
        else:
            st = req.saved_state
            pend = st.get("pending")
            n_pend = 0 if pend is None else int(np.size(pend))
            if len(req.generated) >= req.max_new_tokens:
                raise ValueError(
                    f"resumed request {req.uid} already generated "
                    f"{len(req.generated)}/{req.max_new_tokens} tokens — "
                    "nothing left to decode")
            if int(st["pos"]) + n_pend >= self.scfg.max_len - 1:
                raise ValueError(
                    f"resumed request {req.uid} cannot make progress: "
                    f"pos {int(st['pos'])} + pending {n_pend} >= "
                    f"max_len-1 ({self.scfg.max_len - 1}); it would burn "
                    "a prefill-free slot and finish with zero new tokens")
            worst = (int(st["pos"]) + n_pend + 1
                     + req.max_new_tokens - len(req.generated))
        if self.paged:
            need = blocks_for_tokens(min(worst, self.scfg.max_len),
                                     self.block_size)
            if need > self.pool.num_blocks:
                raise ValueError(
                    f"request {req.uid} may need {need} KV blocks but the "
                    f"pool holds only {self.pool.num_blocks} "
                    f"(kv_pool_blocks); it could never finish")
        if req.arrival is None:
            req.arrival = float(next(self._arrival))
        self.queue.append(req)

    def _rank(self, req: Request):
        from repro.core.scheduler import admission_rank
        return admission_rank(self.scfg.policy, priority=req.priority,
                              arrival=req.arrival, deadline=req.deadline,
                              uid=req.uid)

    def _bucket(self, n: int) -> int:
        for b in self.scfg.prefill_buckets:
            if n <= b:
                return b
        return self.scfg.prefill_buckets[-1]

    def _prefill_fn(self, bucket: int, m: int, extras_sig: tuple):
        """Jitted batched prefill, cached per (bucket, batch, extras)."""
        key = (bucket, m, extras_sig)
        if key not in self._prefills:
            cfg, scfg = self.cfg, self.scfg

            def fn(params, batch, true_len):
                return M.prefill(cfg, params, batch, scfg.max_len,
                                 true_len=true_len)

            self._prefills[key] = jax.jit(fn)
        return self._prefills[key]

    def _sample_first(self, req: Request, logits: np.ndarray) -> int:
        """First generated token, from the admission logits (host-side,
        engine-rng — deterministic for a fixed ServeConfig.seed)."""
        temp = (self.scfg.temperature if req.temperature is None
                else req.temperature)
        top_k = self.scfg.top_k if req.top_k is None else req.top_k
        if temp <= 0:
            return int(np.argmax(logits))
        lg = logits.astype(np.float64)
        if top_k and top_k > 0:
            thresh = np.sort(lg)[::-1][min(top_k, lg.size) - 1]
            lg = np.where(lg < thresh, -np.inf, lg)
        lg = lg / temp
        lg -= lg.max()
        p = np.exp(lg)
        p /= p.sum()
        return int(self._rng.choice(lg.size, p=p))

    # -- paged-pool bookkeeping ----------------------------------------
    def _blocks_needed(self, req: Request) -> int:
        """New pool blocks this request needs to be admitted NOW (the
        prompt's pages + one covering the first decode write; resumed
        requests already hold pages for [0, pos))."""
        if not self.paged:
            return 0
        bs = self.block_size
        if req.saved_state is not None:
            held = len(req.saved_state.get("blocks", ()))
            return max(0, blocks_for_tokens(
                int(req.saved_state["pos"]) + 1, bs) - held)
        n1 = min(len(req.prompt), self.scfg.prefill_buckets[-1])
        return blocks_for_tokens(self._prefix + n1 + 1, bs)

    def _set_table(self, slot: int, blocks: list[int]) -> None:
        self.slot_blocks[slot] = blocks
        self.block_tables[slot, :] = -1
        self.block_tables[slot, :len(blocks)] = blocks

    def _release_slot_blocks(self, slot: int) -> None:
        self.pool.free(self.slot_blocks[slot])
        self._set_table(slot, [])

    def _insert_admitted(self, eng, row, ax, slot: int, phys):
        """Merge a freshly prefilled batch=1 dense cache ``row`` into
        the engine cache: dense leaves land in ``slot``; pool leaves
        scatter the row's global KV strip into the allocated pages
        (``phys``: (n_blk,) physical ids, pool-size padded => dropped).
        """
        if isinstance(eng, dict):
            return {k: self._insert_admitted(eng[k], row[k], ax[k], slot,
                                             phys)
                    for k in eng}
        if ax < 0:
            # eng: (stk, nB, bs, K, hd); row strip: (stk, 1, T, K, hd)
            stk, _, bs = eng.shape[0], eng.shape[1], eng.shape[2]
            blocks = row[:, 0].reshape(stk, -1, bs, *row.shape[3:])
            return eng.at[:, phys].set(blocks.astype(eng.dtype),
                                       mode="drop")
        return jax.lax.dynamic_update_slice_in_dim(
            eng, row.astype(eng.dtype), slot, axis=ax)

    def _place(self, req: Request, slot: int) -> None:
        """Common slot bookkeeping after cache insertion."""
        self.temps[slot] = (self.scfg.temperature if req.temperature is None
                            else req.temperature)
        self.topks[slot] = self.scfg.top_k if req.top_k is None else req.top_k
        self.active[slot] = True
        self.slot_req[slot] = req

    def _admit_resumed(self, req: Request, slot: int) -> None:
        need = self._blocks_needed(req)   # same formula the scan reserved
        st = req.saved_state
        req.saved_state = None
        if self.paged:
            blocks = list(st.get("blocks", ()))
            if need:  # feasibility pre-checked by the admission scan
                blocks += self.pool.alloc(need)
            self._set_table(slot, blocks)
        self.cache = insert_slot(self.cache, st["cache"], slot, self.axes)
        self.pos[slot] = st["pos"]
        self.tokens[slot, 0] = st["last_tok"]
        self.pending[slot] = st["pending"]
        self._place(req, slot)

    def _admit_batch(self) -> None:
        """Admit queued requests into free slots, batching prefill per
        bucket (one compile + one device call per bucket group).

        Capacity-aware: a request is taken only if the pool can cover
        its prompt pages + first decode write.  Requests that don't fit
        right now are skipped, NOT dropped — they wait for pages to
        free (best-effort packing under memory pressure; admission
        order within the feasible set still follows admission_rank)."""
        if not self.queue:
            return
        free = [s for s in range(self.scfg.max_slots) if not self.active[s]]
        if not free:
            return
        self.queue.sort(key=self._rank)
        avail = self.pool.num_free if self.paged else 0
        taken, kept = [], []
        for req in self.queue:
            if not free:
                kept.append(req)
                continue
            need = self._blocks_needed(req)
            if self.paged and need > avail:
                kept.append(req)
                continue
            avail -= need
            taken.append((req, free.pop(0)))
        self.queue = kept

        fresh: dict[tuple, list] = {}   # group key -> [(req, slot)]
        for req, slot in taken:
            if req.saved_state is not None:
                self._admit_resumed(req, slot)
                continue
            n1 = min(len(req.prompt), self.scfg.prefill_buckets[-1])
            bucket = self._bucket(n1)
            sig = tuple(sorted(
                (k, np.asarray(v).shape) for k, v in req.extras.items()))
            fresh.setdefault((bucket, sig), []).append((req, slot))

        for (bucket, sig), group in fresh.items():
            self._admit_group(bucket, sig, group)

    def _admit_group(self, bucket: int, extras_sig: tuple, group) -> None:
        m = len(group)
        prompts = np.zeros((m, bucket), np.int32)
        true_len = np.zeros((m,), np.int32)
        for i, (req, _) in enumerate(group):
            n1 = min(len(req.prompt), bucket)
            # pad value is irrelevant (true_len masks it) — repeat last tok
            prompts[i] = req.prompt[n1 - 1]
            prompts[i, :n1] = req.prompt[:n1]
            true_len[i] = n1
        batch = {"tokens": jnp.asarray(prompts)}
        for k, _ in extras_sig:
            batch[k] = jnp.asarray(
                np.stack([np.asarray(r.extras[k]) for r, _ in group]))
        logits, cache_m = self._prefill_fn(bucket, m, extras_sig)(
            self.params, batch, jnp.asarray(true_len))
        logits_host = np.asarray(logits[:, -1], np.float32)   # (m, V)
        for i, (req, slot) in enumerate(group):
            n1 = int(true_len[i])
            remainder = np.asarray(req.prompt[n1:], np.int32)
            tok = None
            if not remainder.size:
                tok = self._sample_first(req, logits_host[i])
                req.generated.append(tok)
                hit_eos = (self.scfg.eos_id >= 0
                           and tok == self.scfg.eos_id)
                if len(req.generated) >= req.max_new_tokens or hit_eos:
                    # the admission token already completed the request
                    # — never occupy a slot, a page or a decode step
                    req.done = True
                    self.completed.append(req)
                    continue
            row = jax.tree.map(
                lambda leaf, ax: jax.lax.dynamic_slice_in_dim(
                    leaf, i, 1, axis=ax), cache_m, self._dense_axes)
            if self.paged:
                blocks = self.pool.alloc(self._blocks_needed(req))
                self._set_table(slot, blocks)
                phys = np.full((self.n_blk,), self.pool.num_blocks,
                               np.int32)
                phys[:len(blocks)] = blocks
                self.cache = self._insert_admitted(
                    self.cache, row, self.axes, slot, jnp.asarray(phys))
            else:
                self.cache = insert_slot(self.cache, row, slot, self.axes)
            self.pos[slot] = self._prefix + n1
            if remainder.size:
                # chunked prefill: catch up through the decode wave
                self.pending[slot] = remainder[1:]
                self.tokens[slot, 0] = int(remainder[0])
            else:
                self.pending[slot] = None
                self.tokens[slot, 0] = tok
            self._place(req, slot)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _decode_fn(self, params, cache, tokens, pos, temps, topks, key,
                   block_tables=None, any_topk: bool = False):
        if block_tables is None:
            logits, new_cache = M.decode_step(self.cfg, params, cache,
                                              tokens, pos)
        else:
            logits, new_cache = M.decode_step_paged(self.cfg, params, cache,
                                                    tokens, pos,
                                                    block_tables)
        logits = logits[:, -1, :].astype(jnp.float32)          # (B, V)
        greedy = jnp.argmax(logits, axis=-1)
        masked = logits
        if any_topk:
            V = logits.shape[-1]
            desc = jnp.sort(logits, axis=-1)[:, ::-1]
            kth = jnp.take_along_axis(
                desc, jnp.clip(topks - 1, 0, V - 1)[:, None], axis=1)
            masked = jnp.where((topks > 0)[:, None] & (logits < kth),
                               -jnp.inf, logits)
        scaled = masked / jnp.maximum(temps, 1e-6)[:, None]
        sampled = jax.random.categorical(key, scaled, axis=-1)
        nxt = jnp.where(temps > 0, sampled, greedy)
        return nxt.astype(jnp.int32), new_cache

    def _ensure_blocks(self) -> None:
        """Guarantee every active slot's table covers its write
        position ``pos``.  Crossing a block boundary appends one page;
        if the pool is exhausted the slot is preempted back to the
        queue (pages detached) — preempt-or-queue, never a deadlock
        spin.  Best-ranked slots get first pick of the remaining pages.
        """
        bs = self.block_size
        needy = [s for s in range(self.scfg.max_slots)
                 if self.active[s]
                 and int(self.pos[s]) // bs >= len(self.slot_blocks[s])]
        needy.sort(key=lambda s: self._rank(self.slot_req[s]))
        for s in needy:
            j = int(self.pos[s]) // bs
            try:
                blk = self.pool.alloc(1)
            except PoolExhausted:
                req = self.preempt(s)
                self.exhaust_preempts += 1
                self.queue.append(req)   # resumes when a page frees
                continue
            self.slot_blocks[s].extend(blk)
            self.block_tables[s, j] = blk[0]

    def step(self) -> int:
        """Admit queued requests into free slots, then one decode wave.

        Returns the number of active slots that were stepped.
        """
        self._admit_batch()
        if self.paged:
            self._ensure_blocks()
        n_active = int(self.active.sum())
        if n_active == 0:
            return 0
        self.peak_active = max(self.peak_active, n_active)
        if self.paged:
            self.peak_pool_used = max(self.peak_pool_used,
                                      self.pool.num_used)

        self._key, sub = jax.random.split(self._key)
        any_topk = bool((self.topks[self.active] > 0).any())
        tables = (jnp.asarray(self.block_tables) if self.paged else None)
        nxt, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.pos), jnp.asarray(self.temps),
            jnp.asarray(self.topks), sub, tables, any_topk=any_topk)
        nxt_host = np.asarray(nxt)
        for slot in range(self.scfg.max_slots):
            if not self.active[slot]:
                continue
            self.pos[slot] += 1
            req = self.slot_req[slot]
            pend = self.pending[slot]
            out_of_room = int(self.pos[slot]) >= self.scfg.max_len - 1
            if pend is not None and pend.size:
                # still consuming the prompt: teacher-force the next
                # prompt token, discard the sampled one
                self.tokens[slot, 0] = int(pend[0])
                self.pending[slot] = pend[1:]
                if out_of_room:
                    self._finish(slot, req)
                continue
            self.pending[slot] = None
            tok = int(nxt_host[slot])
            self.tokens[slot, 0] = tok
            req.generated.append(tok)
            hit_eos = (self.scfg.eos_id >= 0 and tok == self.scfg.eos_id)
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or out_of_room):
                self._finish(slot, req)
        self.steps += 1
        return n_active

    def _finish(self, slot: int, req: Request) -> None:
        req.done = True
        self.completed.append(req)
        self.active[slot] = False
        self.slot_req[slot] = None
        self.pending[slot] = None
        if self.paged:
            self._release_slot_blocks(slot)

    # ------------------------------------------------------------------
    def preempt(self, slot: int) -> Optional[Request]:
        """Evict a running request (scheduler-driven preemption), taking
        its dense cache leaves and decode position with it; its KV pages
        stay in the pool, DETACHED onto the request — re-submission
        restores the block table and resumes decode exactly where it
        stopped, with NO re-prefill and no page copies."""
        req = self.slot_req[slot]
        if req is None:
            return None
        req.saved_state = {
            "cache": extract_slot(self.cache, slot, self.axes),
            "pos": int(self.pos[slot]),
            "last_tok": int(self.tokens[slot, 0]),
            "pending": self.pending[slot],
        }
        if self.paged:
            req.saved_state["blocks"] = self.slot_blocks[slot]
            self._set_table(slot, [])
        self.active[slot] = False
        self.slot_req[slot] = None
        self.pending[slot] = None
        return req

    # ------------------------------------------------------------------
    def _drop_saved(self, req: Request) -> None:
        """Forced reclaim under pool exhaustion: release the detached
        pages and rebuild the request as a fresh catch-up prompt
        (original prompt + tokens generated so far).  Re-prefill IS
        required for this one request — the escape hatch that keeps
        ``run_until_drained`` live when detached holders own every page.
        The exact context is replayed, but prefill and decode logits
        only agree to bf16 tolerance, so a greedy tie can flip: the
        contract here is liveness + correct token budget, not the
        bit-exactness the detach/resume path guarantees."""
        st = req.saved_state
        req.saved_state = None
        self.pool.free(st.get("blocks", ()))
        # fold only the not-yet-folded suffix of generated into the
        # replay prompt: a request reclaimed twice must not see its
        # first batch of generated tokens duplicated in the context
        folded = getattr(req, "_folded_generated", 0)
        fresh = req.generated[folded:]
        if fresh:
            req.prompt = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(fresh, np.int32)])
            req._folded_generated = len(req.generated)

    def _reclaim(self) -> None:
        holders = [r for r in self.queue
                   if r.saved_state is not None
                   and r.saved_state.get("blocks")]
        if not holders:
            raise RuntimeError(
                "serving pool wedged: no active slots, queue non-empty, "
                "and no detached pages to reclaim (pool misconfigured?)")
        victim = max(holders, key=self._rank)   # worst-ranked holder
        self._drop_saved(victim)
        self.reclaims += 1

    def drain_step(self) -> int:
        """One ``step()`` plus the pool-wedge recovery — the unit of
        progress ``run_until_drained`` iterates.  External drain loops
        that need per-step observability (benchmarks capturing TTFT)
        must use this, not bare ``step()``, or a pool wedged by
        detached holders spins them forever."""
        stepped = self.step()
        if (stepped == 0 and self.paged and self.queue
                and not self.active.any()):
            # requests requeued by _ensure_blocks mid-step (after this
            # step's admission pass) may need zero new pages — give
            # admission one more look before reclaiming
            self._admit_batch()
            if not self.active.any():
                # every queued request is blocked on pool pages held
                # by detached requests: force-reclaim the worst one
                self._reclaim()
        return stepped

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        while (self.queue or self.active.any()) and self.steps < max_steps:
            self.drain_step()
        return self.completed
