"""Multi-tenant serving engine with continuous batching.

The EdgeAI-Hub's inference runtime: fixed-slot batched decode with
per-slot positions (the per-sequence ``pos`` vector threads through
``attention_decode``), batched bucketed admission, and eviction on
EOS / length / preemption.  The hub's scheduler policy
(``core.scheduler.admission_rank``) decides WHO is admitted next; this
module executes it.

Admission semantics (exact, see ``model.prefill(true_len=...)``)
----------------------------------------------------------------
* Prompts are right-padded to the smallest prefill bucket that fits and
  prefilled in one batch per bucket.  ``true_len`` makes the padding
  semantically invisible: admission logits are taken at the true last
  prompt token and pad positions never enter the decode state, so a
  5-token prompt in a 16-token bucket decodes bit-identically to an
  unpadded run.  Slot position starts at ``prefix + true_len`` (prefix =
  VLM image tokens), NOT at the bucket size.  (MoE caveat: expert
  capacity is computed from the static padded/batched shape, so token
  DROPPING under capacity pressure can differ from an unpadded run —
  see ``serving/__init__`` and ``moe._moe_tokens``.)
* Prompts longer than the largest bucket are chunked: the first
  ``max(prefill_buckets)`` tokens go through bucketed prefill, the rest
  catch up through the shared batched decode wave (one prompt token per
  step, teacher-forced, sampled outputs discarded until the prompt is
  consumed).  Catch-up requests ride the same decode batch as running
  requests, so long-prompt admission never stalls other tenants.
* Preemption (``preempt``) extracts the slot's KV/SSM cache and decode
  position onto the request; re-admission reinserts them directly —
  no re-prefill, no lost context.
* Sampling is per-request: ``Request.temperature`` / ``Request.top_k``
  override the engine-wide defaults inside the jitted decode step.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M

# NOTE: repro.core.scheduler is imported lazily in _rank —
# core/__init__ pulls in hub.py, which imports this module back.

Params = Any
_SENTINEL_B = 7777


def cache_batch_axes(cfg: ModelConfig, max_len: int):
    """Pytree of ints: which axis of each cache leaf is the batch axis.

    Discovered structurally by building the cache shape with a sentinel
    batch size — no per-family bookkeeping.
    """
    shapes = jax.eval_shape(
        partial(M.init_cache, cfg, _SENTINEL_B, max_len))
    return jax.tree.map(lambda s: s.shape.index(_SENTINEL_B), shapes)


def insert_slot(cache, one, slot: int, axes):
    """Insert a batch=1 cache ``one`` into batched ``cache`` at ``slot``."""
    return jax.tree.map(
        lambda full, single, ax: jax.lax.dynamic_update_slice_in_dim(
            full, single.astype(full.dtype), slot, axis=ax),
        cache, one, axes)


def extract_slot(cache, slot: int, axes):
    """Slice a batch=1 cache out of batched ``cache`` at ``slot``
    (inverse of ``insert_slot`` — KV-preserving preemption)."""
    return jax.tree.map(
        lambda full, ax: jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=ax),
        cache, axes)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    priority: int = 0                   # higher = more urgent (QoE)
    deadline: Optional[float] = None    # for the "edf" admission policy
    temperature: Optional[float] = None  # None -> ServeConfig.temperature
    top_k: Optional[int] = None          # None -> ServeConfig.top_k
    extras: dict = field(default_factory=dict)  # image/audio embeds
    # filled by the engine:
    generated: list = field(default_factory=list)
    done: bool = False
    arrival: Optional[float] = None     # submission stamp (engine-set)
    saved_state: Optional[dict] = None  # KV snapshot from preemption


@dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 4
    max_len: int = 256
    temperature: float = 0.0            # 0 => greedy
    top_k: int = 0                      # 0 disables top-k filtering
    eos_id: int = -1                    # -1 disables EOS stopping
    prefill_buckets: tuple = (16, 32, 64, 128)
    policy: str = "priority"            # fifo | priority | edf (QoE)
    seed: int = 0


class EdgeServingEngine:
    """Continuous-batching decode engine for one model on one device/mesh."""

    def __init__(self, cfg: ModelConfig, params: Params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        B, T = scfg.max_slots, scfg.max_len
        self.cache = M.init_cache(cfg, B, T)
        self.axes = cache_batch_axes(cfg, T)
        self.tokens = np.zeros((B, 1), np.int32)
        self.pos = np.zeros((B,), np.int32)
        self.temps = np.zeros((B,), np.float32)
        self.topks = np.zeros((B,), np.int32)
        self.active = np.zeros((B,), bool)
        self.slot_req: list[Optional[Request]] = [None] * B
        self.pending: list[Optional[np.ndarray]] = [None] * B
        self.queue: list[Request] = []
        self._key = jax.random.PRNGKey(scfg.seed)
        self._rng = np.random.default_rng(scfg.seed)   # admission sampling
        self._arrival = itertools.count()
        # specialized on the static any_topk flag: the all-greedy /
        # temperature-only path must not pay an O(B·V log V) vocab sort
        # per decoded token (at most two variants ever compile)
        self._decode = jax.jit(self._decode_fn,
                               static_argnames=("any_topk",))
        self._prefills: dict[tuple, Callable] = {}
        self.steps = 0
        self.completed: list[Request] = []

    @property
    def _prefix(self) -> int:
        return self.cfg.num_image_tokens if self.cfg.family == "vlm" else 0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        limit = self.scfg.max_len - 1 - self._prefix
        if req.saved_state is None and len(req.prompt) > limit:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds max_len budget "
                f"{limit} (max_len={self.scfg.max_len})")
        if req.arrival is None:
            req.arrival = float(next(self._arrival))
        self.queue.append(req)

    def _rank(self, req: Request):
        from repro.core.scheduler import admission_rank
        return admission_rank(self.scfg.policy, priority=req.priority,
                              arrival=req.arrival, deadline=req.deadline,
                              uid=req.uid)

    def _bucket(self, n: int) -> int:
        for b in self.scfg.prefill_buckets:
            if n <= b:
                return b
        return self.scfg.prefill_buckets[-1]

    def _prefill_fn(self, bucket: int, m: int, extras_sig: tuple):
        """Jitted batched prefill, cached per (bucket, batch, extras)."""
        key = (bucket, m, extras_sig)
        if key not in self._prefills:
            cfg, scfg = self.cfg, self.scfg

            def fn(params, batch, true_len):
                return M.prefill(cfg, params, batch, scfg.max_len,
                                 true_len=true_len)

            self._prefills[key] = jax.jit(fn)
        return self._prefills[key]

    def _sample_first(self, req: Request, logits: np.ndarray) -> int:
        """First generated token, from the admission logits (host-side,
        engine-rng — deterministic for a fixed ServeConfig.seed)."""
        temp = (self.scfg.temperature if req.temperature is None
                else req.temperature)
        top_k = self.scfg.top_k if req.top_k is None else req.top_k
        if temp <= 0:
            return int(np.argmax(logits))
        lg = logits.astype(np.float64)
        if top_k and top_k > 0:
            thresh = np.sort(lg)[::-1][min(top_k, lg.size) - 1]
            lg = np.where(lg < thresh, -np.inf, lg)
        lg = lg / temp
        lg -= lg.max()
        p = np.exp(lg)
        p /= p.sum()
        return int(self._rng.choice(lg.size, p=p))

    def _place(self, req: Request, slot: int) -> None:
        """Common slot bookkeeping after cache insertion."""
        self.temps[slot] = (self.scfg.temperature if req.temperature is None
                            else req.temperature)
        self.topks[slot] = self.scfg.top_k if req.top_k is None else req.top_k
        self.active[slot] = True
        self.slot_req[slot] = req

    def _admit_resumed(self, req: Request, slot: int) -> None:
        st = req.saved_state
        req.saved_state = None
        self.cache = insert_slot(self.cache, st["cache"], slot, self.axes)
        self.pos[slot] = st["pos"]
        self.tokens[slot, 0] = st["last_tok"]
        self.pending[slot] = st["pending"]
        self._place(req, slot)

    def _admit_batch(self) -> None:
        """Admit queued requests into every free slot, batching prefill
        per bucket (one compile + one device call per bucket group)."""
        if not self.queue:
            return
        free = [s for s in range(self.scfg.max_slots) if not self.active[s]]
        if not free:
            return
        self.queue.sort(key=self._rank)
        taken, self.queue = self.queue[:len(free)], self.queue[len(free):]

        fresh: dict[tuple, list] = {}   # group key -> [(req, slot)]
        for req, slot in zip(taken, free):
            if req.saved_state is not None:
                self._admit_resumed(req, slot)
                continue
            n1 = min(len(req.prompt), self.scfg.prefill_buckets[-1])
            bucket = self._bucket(n1)
            sig = tuple(sorted(
                (k, np.asarray(v).shape) for k, v in req.extras.items()))
            fresh.setdefault((bucket, sig), []).append((req, slot))

        for (bucket, sig), group in fresh.items():
            self._admit_group(bucket, sig, group)

    def _admit_group(self, bucket: int, extras_sig: tuple, group) -> None:
        m = len(group)
        prompts = np.zeros((m, bucket), np.int32)
        true_len = np.zeros((m,), np.int32)
        for i, (req, _) in enumerate(group):
            n1 = min(len(req.prompt), bucket)
            # pad value is irrelevant (true_len masks it) — repeat last tok
            prompts[i] = req.prompt[n1 - 1]
            prompts[i, :n1] = req.prompt[:n1]
            true_len[i] = n1
        batch = {"tokens": jnp.asarray(prompts)}
        for k, _ in extras_sig:
            batch[k] = jnp.asarray(
                np.stack([np.asarray(r.extras[k]) for r, _ in group]))
        logits, cache_m = self._prefill_fn(bucket, m, extras_sig)(
            self.params, batch, jnp.asarray(true_len))
        logits_host = np.asarray(logits[:, -1], np.float32)   # (m, V)
        for i, (req, slot) in enumerate(group):
            row = jax.tree.map(
                lambda leaf, ax: jax.lax.dynamic_slice_in_dim(
                    leaf, i, 1, axis=ax), cache_m, self.axes)
            self.cache = insert_slot(self.cache, row, slot, self.axes)
            n1 = int(true_len[i])
            self.pos[slot] = self._prefix + n1
            remainder = np.asarray(req.prompt[n1:], np.int32)
            if remainder.size:
                # chunked prefill: catch up through the decode wave
                self.pending[slot] = remainder[1:]
                self.tokens[slot, 0] = int(remainder[0])
            else:
                self.pending[slot] = None
                tok = self._sample_first(req, logits_host[i])
                req.generated.append(tok)
                hit_eos = (self.scfg.eos_id >= 0
                           and tok == self.scfg.eos_id)
                if len(req.generated) >= req.max_new_tokens or hit_eos:
                    # the admission token already completed the request
                    # — never occupy a slot or spend a decode step
                    req.done = True
                    self.completed.append(req)
                    continue
                self.tokens[slot, 0] = tok
            self._place(req, slot)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _decode_fn(self, params, cache, tokens, pos, temps, topks, key,
                   any_topk: bool = False):
        logits, new_cache = M.decode_step(self.cfg, params, cache,
                                          tokens, pos)
        logits = logits[:, -1, :].astype(jnp.float32)          # (B, V)
        greedy = jnp.argmax(logits, axis=-1)
        masked = logits
        if any_topk:
            V = logits.shape[-1]
            desc = jnp.sort(logits, axis=-1)[:, ::-1]
            kth = jnp.take_along_axis(
                desc, jnp.clip(topks - 1, 0, V - 1)[:, None], axis=1)
            masked = jnp.where((topks > 0)[:, None] & (logits < kth),
                               -jnp.inf, logits)
        scaled = masked / jnp.maximum(temps, 1e-6)[:, None]
        sampled = jax.random.categorical(key, scaled, axis=-1)
        nxt = jnp.where(temps > 0, sampled, greedy)
        return nxt.astype(jnp.int32), new_cache

    def step(self) -> int:
        """Admit queued requests into free slots, then one decode wave.

        Returns the number of active slots that were stepped.
        """
        self._admit_batch()
        n_active = int(self.active.sum())
        if n_active == 0:
            return 0

        self._key, sub = jax.random.split(self._key)
        any_topk = bool((self.topks[self.active] > 0).any())
        nxt, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.pos), jnp.asarray(self.temps),
            jnp.asarray(self.topks), sub, any_topk=any_topk)
        nxt_host = np.asarray(nxt)
        for slot in range(self.scfg.max_slots):
            if not self.active[slot]:
                continue
            self.pos[slot] += 1
            req = self.slot_req[slot]
            pend = self.pending[slot]
            out_of_room = int(self.pos[slot]) >= self.scfg.max_len - 1
            if pend is not None and pend.size:
                # still consuming the prompt: teacher-force the next
                # prompt token, discard the sampled one
                self.tokens[slot, 0] = int(pend[0])
                self.pending[slot] = pend[1:]
                if out_of_room:
                    self._finish(slot, req)
                continue
            self.pending[slot] = None
            tok = int(nxt_host[slot])
            self.tokens[slot, 0] = tok
            req.generated.append(tok)
            hit_eos = (self.scfg.eos_id >= 0 and tok == self.scfg.eos_id)
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or out_of_room):
                self._finish(slot, req)
        self.steps += 1
        return n_active

    def _finish(self, slot: int, req: Request) -> None:
        req.done = True
        self.completed.append(req)
        self.active[slot] = False
        self.slot_req[slot] = None
        self.pending[slot] = None

    # ------------------------------------------------------------------
    def preempt(self, slot: int) -> Optional[Request]:
        """Evict a running request (scheduler-driven preemption), taking
        its KV/SSM cache with it — re-submission resumes decode exactly
        where it stopped, with NO re-prefill."""
        req = self.slot_req[slot]
        if req is None:
            return None
        req.saved_state = {
            "cache": extract_slot(self.cache, slot, self.axes),
            "pos": int(self.pos[slot]),
            "last_tok": int(self.tokens[slot, 0]),
            "pending": self.pending[slot],
        }
        self.active[slot] = False
        self.slot_req[slot] = None
        self.pending[slot] = None
        return req

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        while (self.queue or self.active.any()) and self.steps < max_steps:
            self.step()
        return self.completed
