"""Multi-tenant serving engine with continuous batching.

The EdgeAI-Hub's inference runtime: fixed-slot batched decode with
per-slot positions (the per-sequence ``pos`` vector threads through
``attention_decode``), slot-level admission (prefill one request, insert
its cache into the batch along the discovered batch axes) and eviction
on EOS / length / preemption.  The hub's scheduler (core.scheduler)
decides WHICH queued request is admitted; this module executes it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M

Params = Any
_SENTINEL_B = 7777


def cache_batch_axes(cfg: ModelConfig, max_len: int):
    """Pytree of ints: which axis of each cache leaf is the batch axis.

    Discovered structurally by building the cache shape with a sentinel
    batch size — no per-family bookkeeping.
    """
    shapes = jax.eval_shape(
        partial(M.init_cache, cfg, _SENTINEL_B, max_len))
    return jax.tree.map(lambda s: s.shape.index(_SENTINEL_B), shapes)


def insert_slot(cache, one, slot: int, axes):
    """Insert a batch=1 cache ``one`` into batched ``cache`` at ``slot``."""
    return jax.tree.map(
        lambda full, single, ax: jax.lax.dynamic_update_slice_in_dim(
            full, single.astype(full.dtype), slot, axis=ax),
        cache, one, axes)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    priority: int = 0                   # higher = more urgent (QoE)
    extras: dict = field(default_factory=dict)  # image/audio embeds
    # filled by the engine:
    generated: list = field(default_factory=list)
    done: bool = False


@dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 4
    max_len: int = 256
    temperature: float = 0.0            # 0 => greedy
    eos_id: int = -1                    # -1 disables EOS stopping
    prefill_buckets: tuple = (16, 32, 64, 128)
    seed: int = 0


class EdgeServingEngine:
    """Continuous-batching decode engine for one model on one device/mesh."""

    def __init__(self, cfg: ModelConfig, params: Params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        B, T = scfg.max_slots, scfg.max_len
        self.cache = M.init_cache(cfg, B, T)
        self.axes = cache_batch_axes(cfg, T)
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.pos = jnp.zeros((B,), jnp.int32)
        self.active = np.zeros((B,), bool)
        self.slot_req: list[Optional[Request]] = [None] * B
        self.queue: list[Request] = []
        self._key = jax.random.PRNGKey(scfg.seed)
        self._decode = jax.jit(self._decode_fn)
        self._prefills: dict[int, Callable] = {}
        self.steps = 0
        self.completed: list[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        for b in self.scfg.prefill_buckets:
            if n <= b:
                return b
        return self.scfg.prefill_buckets[-1]

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            cfg, scfg = self.cfg, self.scfg

            def fn(params, batch, true_len):
                logits, cache = M.prefill(cfg, params, batch, scfg.max_len)
                return logits, cache

            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    def _admit(self, req: Request, slot: int) -> None:
        n = len(req.prompt)
        bucket = self._bucket(n)
        # left-pad-free: pad right with repeats of last token, position
        # masking below keeps semantics exact for causal decode
        prompt = np.full((bucket,), req.prompt[-1], np.int32)
        prompt[:n] = req.prompt
        batch = {"tokens": jnp.asarray(prompt)[None]}
        for k, v in req.extras.items():
            batch[k] = jnp.asarray(v)[None]
        logits, cache1 = self._prefill_fn(bucket)(
            self.params, batch, n)
        # pick logits of the true last prompt token
        # (prefill returns last-position logits; for padded prompts we
        #  re-run decode masking — bucket == n is exact; else approximate
        #  admission at position n)
        self.cache = insert_slot(self.cache, cache1, slot, self.axes)
        prefix = (self.cfg.num_image_tokens
                  if self.cfg.family == "vlm" else 0)
        self.pos = self.pos.at[slot].set(prefix + bucket)
        next_tok = int(jnp.argmax(logits[0, -1]))
        self.tokens = self.tokens.at[slot, 0].set(next_tok)
        req.generated.append(next_tok)
        self.active[slot] = True
        self.slot_req[slot] = req

    # ------------------------------------------------------------------
    def _decode_fn(self, params, cache, tokens, pos, key):
        logits, new_cache = M.decode_step(self.cfg, params, cache,
                                          tokens, pos)
        logits = logits[:, -1, :]
        if self.scfg.temperature > 0:
            nxt = jax.random.categorical(
                key, logits / self.scfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), new_cache

    def step(self) -> int:
        """Admit queued requests into free slots, then one decode wave.

        Returns the number of active slots that were stepped.
        """
        # admission (highest priority first — QoE ordering)
        self.queue.sort(key=lambda r: -r.priority)
        for slot in range(self.scfg.max_slots):
            if not self.queue:
                break
            if not self.active[slot]:
                self._admit(self.queue.pop(0), slot)

        n_active = int(self.active.sum())
        if n_active == 0:
            return 0

        self._key, sub = jax.random.split(self._key)
        nxt, self.cache = self._decode(self.params, self.cache,
                                       self.tokens, self.pos, sub)
        self.pos = self.pos + jnp.asarray(self.active, jnp.int32)
        self.tokens = jnp.where(jnp.asarray(self.active)[:, None],
                                nxt[:, None], self.tokens)
        nxt_host = np.asarray(nxt)
        for slot in range(self.scfg.max_slots):
            if not self.active[slot]:
                continue
            req = self.slot_req[slot]
            tok = int(nxt_host[slot])
            req.generated.append(tok)
            hit_eos = (self.scfg.eos_id >= 0 and tok == self.scfg.eos_id)
            out_of_room = int(self.pos[slot]) >= self.scfg.max_len - 1
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or out_of_room):
                req.done = True
                self.completed.append(req)
                self.active[slot] = False
                self.slot_req[slot] = None
        self.steps += 1
        return n_active

    def preempt(self, slot: int) -> Optional[Request]:
        """Evict a running request (scheduler-driven preemption); it can
        be re-submitted later (prompt + generated so far)."""
        req = self.slot_req[slot]
        if req is None:
            return None
        self.active[slot] = False
        self.slot_req[slot] = None
        req.prompt = np.concatenate(
            [req.prompt, np.asarray(req.generated, np.int32)])
        return req

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        while (self.queue or self.active.any()) and self.steps < max_steps:
            self.step()
        return self.completed
