"""Step-driven multi-tenant serving engine: continuous batching over a
paged KV cache with no drain assumption.

The EdgeAI-Hub's inference runtime.  The unit of work is one
``step()`` — admit, plan, one jitted wave, retire — and the engine
makes progress with whatever frontier it has *right now*: requests
arrive between any two steps (``submit``), leave between any two steps
(``cancel``), and an always-on frontend (``launch.serve``) just loops
``step()`` forever.  ``run_until_drained`` is a thin compatibility
wrapper, not the execution model.

Step-driven lifecycle (admit -> plan -> wave -> retire)
-------------------------------------------------------
* ADMIT — ``core.scheduler.admission_rank`` orders the queue (QoE
  policies: fifo / priority / edf); capacity-aware admission binds
  requests to free slots.  With ``ServeConfig.chunked_prefill`` a
  token-only request skips bucketed prefill entirely: admission is
  pure bookkeeping (``_admit_wave``) and the prompt becomes pending
  catch-up tokens — its chunks are just more spans in the wave plan
  (Sarathi-style), so a long prompt never blocks in-flight decodes
  behind a monolithic prefill.  (Requests carrying extras — VLM image
  embeds, enc-dec audio — still prefill the smallest bucket first,
  since extras only enter the state through prefill.)
* PLAN — each active slot gets a wave span ``(mode, width)``: ``spec``
  (draft-backed verify of up to ``spec_gamma`` tokens), ``catch``
  (teacher-forced prompt catch-up of up to ``catch_chunk`` tokens) or
  ``plain`` (one decode token).  ``ServeConfig.wave_tokens`` is the
  per-wave token budget: ``core.scheduler.plan_wave`` grants every
  slot width >= 1 (liveness) and spends the rest best-rank-first, so
  prefill chunks and decode share one budget under the same QoE
  policy.  The plan is observable at ``engine.last_plan``
  (``scripts/diagnose.py --server``).
* WAVE — ONE jitted call executes the whole plan: mixed spec / catch /
  plain spans ride a single ``model.extend_paged`` (or ``extend``)
  wave; padded rows drop their writes.  Chunk boundaries and
  budget-driven width changes are pure schedule: extend is bitwise
  equal to sequential decode, so tokens never depend on the plan.
* RETIRE — committed tokens land in ``Request.generated``; finished
  slots return their pages to the radix cache (``_finish``), frontier
  pages publish for in-flight sharing, and EOS / length / preemption /
  cancellation free the slot for the next admit.

Cancellation (``cancel(uid)``) mirrors ``_finish``: a live slot's
pages below ``pos`` hold a valid chain and retire into the radix cache
— published frontier pages keep their cache reference, so concurrent
readers of the cancelled chain are untouched; queued or preempted
requests free their detached state.  Zero pages leak in any phase
(``tests/test_cancellation.py``).

Paged KV (block-table decode contract)
--------------------------------------
GLOBAL attention layers no longer own a dense ``max_len`` strip per
slot.  Their K/V lives in a shared pool of ``kv_block_size``-token
pages (``models.layers.init_kv_pages``, allocated by
``kv_pool.KVBlockPool``); each slot holds an ordered list of physical
page ids whose device mirror is the ``(max_slots, max_len //
kv_block_size)`` int32 ``block_tables`` array passed to
``model.decode_step_paged`` every step (-1 = unallocated).  The engine
maintains these invariants:

* before a decode wave, every active slot's table covers its write
  position ``pos`` (``_ensure_blocks`` appends a page on boundary
  crossing; on pool exhaustion the slot is preempted back to the queue
  with its pages detached — "preempt-or-queue");
* admission is capacity-aware: a request is admitted only when enough
  pool blocks exist for its prompt (+1 decode write) counting both
  FREE pages and radix-cache pages evictable right now — not merely
  when a slot is free;
* ``_finish`` RETURNS the slot's full pages to the radix prefix cache
  (sharable configs; the partial tail page and any duplicates of an
  already-indexed prefix are freed) instead of freeing them outright;
  ``preempt`` detaches pages onto ``Request.saved_state`` so resume is
  still re-prefill-free;
* the logical view ``n_blk * kv_block_size == max_len`` makes paged
  decode bit-for-bit identical to the dense path — only HBM residency
  shrinks, from ``max_slots x max_len`` strips to tokens actually in
  flight.

Shared / forked pages (prefix-cache ownership contract)
-------------------------------------------------------
Admission prefill writes prompt K/V DIRECTLY into pages
(``model.prefill_paged`` — no dense strip is materialised and shadow-
copied), which is what lets a radix-cache hit skip prefix prefill
entirely: admission looks the prompt up in ``prefix_cache``
(``serving.prefix_cache.RadixPrefixCache``), increfs the matched chain
and prefills ONLY the unmatched suffix at the chain's end position.
Matching is TOKEN-granular: a hit may end in the middle of a page —
because the query diverges inside a cached page, or because the cached
chain itself ends mid-page (finished chains retire WITH their partial
tail page indexed).  Admission then CoW-forks that one page
(``KVBlockPool.fork`` + device page copy) so the row owns it
privately, and the suffix prefill reads the forked prefix bytes below
``ctx_len`` while scattering its own K/V from ``ctx_len`` onward
through the row's full block table.  The ownership rules:

* a slot's block table may reference pages with refcount > 1 (shared
  prefix, detached twins, in-flight published frontiers); such pages
  are READ-ONLY by construction — every page a suffix/decode/verify
  wave could write is either freshly allocated or was forked private
  at admission.  The per-step ``_cow_guard`` is the backstop: any slot
  whose write span lands in a page with >1 owner trades it for a
  private copy before the wave runs;
* IN-FLIGHT sharing: after every committed wave each live slot
  publishes its pages below the frontier ``floor(pos / block_size) *
  block_size`` into the radix tree (``_publish_frontiers``; the cache
  takes its own reference, duplicate re-publications dedup to
  nothing).  A later request can therefore hit a chain that is still
  decoding: readers pin pages strictly below the frontier, the writer
  only writes at/above ``pos``, and spec-decode rollback
  (``_truncate_slot``) frees only pages above ``pos`` — published
  pages are never written, truncated or evicted from under a reader;
* finished chains are indexed under a key of the full token sequence
  (plus a digest namespace for non-token inputs: VLM image embeds,
  enc-dec audio — their K/V depends on more than token ids); the cache
  holds one reference per indexed page;
* eviction (LRU leaf chains whose pages have refcount 1) runs lazily
  under pool pressure (``_reserve``) — a chain pinned by any reader or
  published by a live slot is never evicted;
* PERSISTENCE: with ``ServeConfig.prefix_persist_path`` set,
  ``close()`` serializes the hot refcount-free chains (token keys +
  page bytes per pool leaf; chains evicted under pressure are spilled
  to the host first) via ``prefix_cache.save_store``, and a new engine
  constructed with the same path rehydrates them — a restarted hub
  serves warm-TTFT hits from step one.  The store header pins page
  geometry, a config digest and a params fingerprint; a corrupt or
  mismatched store is rejected cleanly (``stats()['persist_rejected']``)
  and the engine starts cold;
* sharing is behaviour-invariant: tokens decoded after a prefix hit —
  block-aligned, token-granular, in-flight or rehydrated-from-disk —
  are bit-identical to a cold run (asserted per family in
  ``tests/test_prefix_cache.py`` / ``tests/test_prefix_persist.py``).
  Configs whose decode state is not fully reconstructible from pages
  (local-ring gemma patterns, ssm/hybrid recurrences) never share —
  ``model.prefix_sharable`` gates the cache off and admission stays
  the cold path.

Local ring-window layers stay dense at ``W`` and SSM state is O(1), so
families with no global KV layers (ssm, hybrid) transparently run the
dense path with zero pool demand.

Speculative decoding (draft/verify step contract)
-------------------------------------------------
With ``ServeConfig.spec_decode`` on a ``model.spec_decodable`` config,
every wave is a multi-token EXTEND wave instead of a one-token decode
(``serving.spec_decode`` owns the draft runtime and acceptance rules;
this engine owns the batching and the KV bookkeeping; the dense
``paged=False`` twin runs the same waves via ``model.extend`` and
stays wave-for-wave bit-identical):

* the draft model (own dense cache, one row per slot) proposes up to
  ``spec_gamma - 1`` tokens per slot; ONE jitted
  ``model.extend_paged`` call then scores ``[t0, d_1..d_{v-1}]`` for
  every slot — spec, catch-up and plain slots share the wave, padded
  rows drop their writes;
* before the wave, ``_ensure_blocks``/``_cow_guard`` cover the whole
  write span ``[pos, pos + v)``: a verify over shared prefix-cache
  pages forks them (copy-on-write) first — a speculative write can
  never land in a chain another reader holds;
* acceptance (greedy exact-match, or rejection sampling at
  temperature > 0 — the emitted distribution equals vanilla sampling)
  commits ``n_accepted + 1`` tokens; the verify wave's rejected writes
  sit ABOVE the new frontier where every context read masks them, so
  KV rollback is ``_truncate_slot``: whole tail pages past the
  frontier go back to the pool on block boundaries and
  ``pool.assert_consistent()`` holds after every drain_step, rejected
  runs included;
* greedy speculative output is bit-identical to vanilla decode
  (``extend_paged`` reproduces sequential decode exactly; acceptance
  only keeps argmax matches); draft quality moves ONLY the acceptance
  rate / tokens-per-round counters in ``stats()``, never the tokens.

Admission semantics (exact, see ``model.prefill(true_len=...)``)
----------------------------------------------------------------
* Prompts are right-padded to the smallest prefill bucket that fits and
  prefilled in one batch per bucket.  ``true_len`` makes the padding
  semantically invisible: admission logits are taken at the true last
  prompt token and pad positions never enter the decode state, so a
  5-token prompt in a 16-token bucket decodes bit-identically to an
  unpadded run.  Slot position starts at ``prefix + true_len`` (prefix =
  VLM image tokens), NOT at the bucket size.  (MoE caveat: expert
  capacity is computed from the static padded/batched shape, so token
  DROPPING under capacity pressure can differ from an unpadded run —
  see ``serving/__init__`` and ``moe._moe_tokens``.)
* Prompts longer than the largest bucket are chunked: the first
  ``max(prefill_buckets)`` tokens go through bucketed prefill, the rest
  catch up teacher-forced through the shared wave — ``spec_gamma``
  prompt tokens per multi-token extend wave on extend-capable configs
  (``model.extendable``: all attention families, paged and dense
  engines alike), one per decode step only on the recurrent families
  (ssm/hybrid).
  Sampled outputs are discarded until the prompt is consumed, and
  catch-up requests ride the same batch as running requests, so
  long-prompt admission never stalls other tenants.
* Preemption (``preempt``) extracts the slot's dense cache leaves and
  decode position onto the request and detaches its KV pages;
  re-admission reinserts them directly — no re-prefill, no page copies,
  no lost context.
* ``submit`` validates resumed requests too: a saved state with no room
  left to generate (``pos + pending >= max_len - 1``) or nothing left
  to generate is rejected instead of burning a slot.
* Sampling is per-request: ``Request.temperature`` / ``Request.top_k``
  override the engine-wide defaults inside the jitted decode step.
"""
from __future__ import annotations

import hashlib
import itertools
import os
from contextlib import nullcontext
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.kv_pool import KVBlockPool, PoolExhausted, \
    blocks_for_tokens
from repro.serving.prefix_cache import (PrefixStoreError, RadixPrefixCache,
                                        dump_chains, load_store, save_store)
from repro.serving.telemetry import SLOT_TID0, MetricsRegistry, Tracer

# NOTE: repro.core.scheduler is imported lazily in _rank —
# core/__init__ pulls in hub.py, which imports this module back.

Params = Any

# Batch-axis discovery probes: the cache is shape-evaluated at TWO
# distinct batch sizes and the batch axis is the (unique) axis whose
# extent changed.  This cannot collide with any other cache dimension —
# the previous single-sentinel scheme (`shape.index(7777)`) silently
# picked the wrong axis whenever max_len/vocab/d_model happened to
# equal the sentinel.
_PROBE_A, _PROBE_B = 3, 5


def _diff_axis(a, b) -> int:
    """Axis where the two probe shapes differ; -1 when none does (a
    batchless shared-pool leaf)."""
    diffs = [i for i, (p, q) in enumerate(zip(a.shape, b.shape)) if p != q]
    if not diffs:
        return -1
    if len(diffs) > 1:
        raise ValueError(
            f"ambiguous batch axis: shapes {a.shape} / {b.shape} differ "
            f"on {diffs}")
    return diffs[0]


def cache_batch_axes(cfg: ModelConfig, max_len: int):
    """Pytree of ints: which axis of each cache leaf is the batch axis.

    Discovered structurally by shape-evaluating the cache at two batch
    sizes — no per-family bookkeeping, no sentinel collisions.
    """
    s1 = jax.eval_shape(partial(M.init_cache, cfg, _PROBE_A, max_len))
    s2 = jax.eval_shape(partial(M.init_cache, cfg, _PROBE_B, max_len))
    return jax.tree.map(_diff_axis, s1, s2)


def paged_cache_axes(cfg: ModelConfig, max_len: int, num_blocks: int,
                     block_size: int, kv_dtype=None):
    """Like ``cache_batch_axes`` for the paged cache: shared page-pool
    leaves have no batch axis and map to -1.  With ``kv_dtype="int8"``
    the probe includes the ``k_scale``/``v_scale`` pool leaves, which
    map to -1 like their int8 K/V twins — all generic pool-leaf
    machinery (CoW copies, chain gathers, persistence) rides on these
    axes and so covers the scales with no special cases."""
    s1 = jax.eval_shape(partial(M.init_paged_cache, cfg, _PROBE_A, max_len,
                                num_blocks, block_size, kv_dtype=kv_dtype))
    s2 = jax.eval_shape(partial(M.init_paged_cache, cfg, _PROBE_B, max_len,
                                num_blocks, block_size, kv_dtype=kv_dtype))
    return jax.tree.map(_diff_axis, s1, s2)


def insert_slot(cache, one, slot: int, axes):
    """Insert a batch=1 cache ``one`` into batched ``cache`` at ``slot``.
    Pool leaves (axis -1) are left untouched — their content lives in
    shared pages addressed by block tables, not per-slot strips."""
    return jax.tree.map(
        lambda full, single, ax: full if ax < 0 else
        jax.lax.dynamic_update_slice_in_dim(
            full, single.astype(full.dtype), slot, axis=ax),
        cache, one, axes)


def extract_slot(cache, slot: int, axes):
    """Slice a batch=1 cache out of batched ``cache`` at ``slot``
    (inverse of ``insert_slot`` — KV-preserving preemption).  Pool
    leaves yield an empty placeholder; their pages are detached via the
    block table instead of copied."""
    return jax.tree.map(
        lambda full, ax: jnp.zeros((0,), full.dtype) if ax < 0 else
        jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=ax),
        cache, axes)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    priority: int = 0                   # higher = more urgent (QoE)
    deadline: Optional[float] = None    # for the "edf" admission policy
    temperature: Optional[float] = None  # None -> ServeConfig.temperature
    top_k: Optional[int] = None          # None -> ServeConfig.top_k
    extras: dict = field(default_factory=dict)  # image/audio embeds
    # filled by the engine:
    generated: list = field(default_factory=list)
    done: bool = False
    cancelled: bool = False             # set by engine.cancel(uid)
    arrival: Optional[float] = None     # submission stamp (engine-set)
    saved_state: Optional[dict] = None  # KV snapshot from preemption


@dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 4
    max_len: int = 256
    temperature: float = 0.0            # 0 => greedy
    top_k: int = 0                      # 0 disables top-k filtering
    eos_id: int = -1                    # -1 disables EOS stopping
    prefill_buckets: tuple = (16, 32, 64, 128)
    policy: str = "priority"            # fifo | priority | edf (QoE)
    seed: int = 0
    # paged KV pool (tokens-in-flight memory ceiling instead of
    # max_slots * max_len strips); paged=False restores dense strips
    paged: bool = True
    kv_block_size: int = 16
    kv_pool_blocks: Optional[int] = None  # None -> max_slots*max_len/bs
    # radix prefix cache: finished chains stay indexed for copy-free
    # sharing (only engages on prefix-sharable configs, see
    # model.prefix_sharable; pages are reclaimed LRU under pressure).
    # Matching is TOKEN-granular (a hit may end mid-page; the partial
    # page is CoW-forked at admission) and live slots publish their
    # committed-prefix frontier every wave, so concurrent same-prefix
    # tenants share in flight, not just after the first one finishes.
    prefix_cache: bool = True
    # host-side prefix store: on close() the hot refcount-free chains
    # (token keys + page bytes) are serialized here, and a new engine
    # constructed with the same path rehydrates them — a restarted hub
    # serves warm-TTFT hits immediately.  A corrupt or mismatched-
    # config store is rejected cleanly (fresh cold start, no crash);
    # see serving/prefix_cache.py save_store/load_store.
    prefix_persist_path: Optional[str] = None
    # read paged decode KV through the Pallas paged_attention kernel
    # (scalar-prefetched block tables) instead of the jnp gather —
    # the TPU serving path; default off (gather is the portable twin)
    use_pallas_paged: bool = False
    # speculative decoding (serving/spec_decode.py): a resident draft
    # model proposes spec_gamma tokens per slot and the big model
    # verifies them in ONE extend_paged wave.  draft_arch: registry id
    # of the draft ("self" / None = early-exit self-draft; callers with
    # real draft weights pass `draft=(cfg, params)` to the engine).
    # Engages only on model.spec_decodable configs (quiet vanilla
    # fallback otherwise, mirroring prefix_cache) — on BOTH engines:
    # the dense paged=False twin speculates wave-for-wave identically
    # (slots-masked strips roll back like pages do).  An incompatible
    # draft (vocab mismatch, extras the requests cannot supply) or an
    # out-of-bounds gamma is rejected at engine construction.
    spec_decode: bool = False
    draft_arch: Optional[str] = None
    # also the chunk width of multi-token catch-up prefill (prompts
    # past the largest bucket consume spec_gamma prompt tokens per
    # extend wave instead of 1 per decode step)
    spec_gamma: int = 4
    # ---- step-driven wave plan (Sarathi-style chunked prefill) ----
    # chunked_prefill=True admits prompts as WAVE SPANS: a new
    # request's prompt enters through the same extend wave the decode
    # slots ride (no blocking bucketed prefill call at all for
    # token-only requests; requests carrying extras — VLM images,
    # enc-dec audio — still prefill one minimal bucket first, since
    # embeddings can only enter through prefill).  Prompt chunking is a
    # pure schedule change on extend-capable configs (extend is
    # bit-identical to sequential decode), but prefill and extend only
    # agree to float tolerance — so a chunked engine's tokens match a
    # chunked reference, not a prefill-admitted one.
    chunked_prefill: bool = False
    # max prompt tokens one catch-up slot consumes per extend wave
    # (None -> spec_gamma); raises the static extend width to
    # max(spec_gamma, catch_chunk)
    catch_chunk: Optional[int] = None
    # per-wave token budget across the live admit/decode frontier
    # (core.scheduler.plan_wave ranks slots by the admission policy and
    # shrinks catch-up / speculative widths to fit; every active slot
    # is always granted >= 1 token).  None = unbudgeted.
    wave_tokens: Optional[int] = None
    # prefix-cache admission floor: a radix match shorter than this
    # many tokens is treated as a miss (a 1-token accidental hit would
    # CoW-fork a page for near-zero reuse).  1 = accept any hit.
    min_match_tokens: int = 1
    # ---- quantized serving (capacity lever: edge hubs are pool-bound) --
    # quant_kv="int8" stores the paged pool's K/V as int8 with one f32
    # scale per (page, token-offset, kv-head) head_dim vector riding in
    # parallel k_scale/v_scale pool leaves (~4/head_dim byte overhead;
    # ~3.8x pool capacity at head_dim 64).  Scales are write-once like
    # the pages themselves, so CoW/rollback/in-flight sharing semantics
    # are unchanged, and persistence spills int8 bytes + scales (the
    # store header pins the layout: an f32<->int8 store mismatch is a
    # clean cold start).  Decode/extend logits shift within a small
    # per-family tolerance (gated in tests/test_engine_matrix.py, NOT
    # bit-exact); use_pallas_paged additionally fuses the dequant into
    # the Pallas paged decode AND extend kernels so the f32 pool never
    # materialises.  None = f32 pool, every path stays bit-exact.
    # Families with no pages (ssm, hybrid) quietly ignore it.
    quant_kv: Optional[str] = None
    # quantize the DRAFT model's weights to int8 (per-out-channel
    # scales, models.layers.quantize_matmul_params; TPU matmuls go
    # through the kernels.quant_matmul Pallas kernel).  Greedy spec
    # output stays BIT-exact — the verify model is untouched; only the
    # acceptance rate (perf, not correctness) can shift.  Rejected for
    # the early-exit self-draft, which shares the verify trunk by
    # reference (quantizing would materialise a copy instead of saving
    # memory).
    quant_draft: bool = False
    # ---- telemetry (serving/telemetry.py) ----
    # trace=True records engine-phase spans (admit / plan / dispatch /
    # device sync / retire / publish), per-slot residency tracks and
    # per-request lifecycle events (TTFT decomposition, ITL series,
    # per-round speculative acceptance), exported as Perfetto JSON via
    # engine.dump_chrome_trace(path).  Tracing only OBSERVES: generated
    # tokens are bit-identical to an untraced run (the only extra
    # device call is a value-neutral block_until_ready that fences the
    # sync span).  The metrics registry (engine.metrics) is always on —
    # stats() is a compatibility view over it, traced or not.
    trace: bool = False
    # monotonic clock the tracer stamps against (None =
    # telemetry.default_clock, i.e. time.perf_counter).  Injectable so
    # a replayed trace — fed a deterministic fake clock — is
    # byte-reproducible in tests.
    trace_clock: Optional[Callable[[], float]] = None


class EdgeServingEngine:
    """Continuous-batching decode engine for one model on one device/mesh.

    ``draft``: optional ``(draft_cfg, draft_params)`` for speculative
    decoding — overrides ``ServeConfig.draft_arch`` (which builds a
    randomly-initialised registry smoke draft, or an early-exit
    self-draft for ``"self"``/``None``).
    """

    def __init__(self, cfg: ModelConfig, params: Params, scfg: ServeConfig,
                 draft=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        B, T = scfg.max_slots, scfg.max_len
        bs = scfg.kv_block_size
        if scfg.quant_kv not in (None, "int8"):
            raise ValueError(
                f"quant_kv must be None or 'int8', got {scfg.quant_kv!r}")
        self.paged = bool(scfg.paged)
        # quantization only exists as a POOL layout; the dense twin
        # keeps f32 strips (it is the bit-exact reference the quantized
        # engine is tolerance-gated against)
        self.quant = bool(self.paged and scfg.quant_kv == "int8")
        if self.paged:
            if bs < 1:
                raise ValueError(f"kv_block_size must be >= 1, got {bs}")
            # the logical page view must tile max_len exactly (that is
            # what makes paged == dense bit-for-bit); shrink the block
            # size until it divides rather than reject the config
            while T % bs:
                bs //= 2
            self.n_blk = T // bs
            if scfg.kv_pool_blocks:
                # a user-set pool is a TOKEN budget: if the block size
                # shrank, keep blocks x block_size constant instead of
                # silently shrinking the budget by the same factor
                n_pool = scfg.kv_pool_blocks * scfg.kv_block_size // bs
            else:
                n_pool = B * self.n_blk
            axes = paged_cache_axes(cfg, T, n_pool, bs,
                                    kv_dtype=scfg.quant_kv)
            # families with no global KV layers (ssm, hybrid ring) have
            # zero pool demand — run them on the dense path outright
            self.paged = any(a < 0 for a in jax.tree.leaves(axes))
        self.block_size = bs              # effective page size
        self.quant = bool(self.paged and scfg.quant_kv == "int8")
        if self.paged:
            self.axes = axes
            self.pool = KVBlockPool(n_pool, bs)
            self.cache = M.init_paged_cache(cfg, B, T, n_pool, bs,
                                            kv_dtype=scfg.quant_kv)
            self.block_tables = np.full((B, self.n_blk), -1, np.int32)
            self.slot_blocks: list[list[int]] = [[] for _ in range(B)]
        else:
            self.pool = None
            self.cache = M.init_cache(cfg, B, T)
            self.axes = cache_batch_axes(cfg, T)
        # radix prefix cache: only for configs whose full decode state
        # lives in pages (model.prefix_sharable) — otherwise a hit
        # could not reconstruct ring/recurrent state and sharing would
        # change behaviour
        self.sharable = bool(self.paged and scfg.prefix_cache
                             and M.prefix_sharable(cfg))
        self.prefix_cache = (RadixPrefixCache(
            self.pool, bs, min_match_tokens=scfg.min_match_tokens)
            if self.sharable else None)
        # persistence: chains evicted under pressure are spilled to the
        # host (page bytes captured BEFORE the pool reclaims them) and
        # merged into the close()-time store; a store left by a previous
        # engine with the same path/config rehydrates below
        self._spilled: list = []
        self.persist_loaded_chains = 0
        self.persist_loaded_blocks = 0
        self.persist_rejected = ""
        if self.sharable and scfg.prefix_persist_path:
            self.prefix_cache.on_evict = self._spill_chain
            self._load_prefix_store(scfg.prefix_persist_path)
        # in-flight sharing: tokens (page-aligned) each slot has already
        # published to the radix tree; readers admitted below this
        # frontier share a chain that is STILL decoding
        self.slot_published = [0] * B
        self.published_frontiers = 0
        # multi-token extend path (speculative verify + chunked catch-up
        # consuming spec_gamma tokens per wave): every family that
        # implements extend/extend_paged, on BOTH engines (the dense
        # twin stays wave-for-wave identical to the paged one);
        # gemma-pattern local rings additionally need the chunk to fit
        # the window
        W = min(cfg.local_window, T)
        # static extend-wave width: gamma for speculation, or the
        # catch-up chunk if larger (chunked prefill wants wide catch
        # spans; at most two jit variants compile either way)
        self.K = max(scfg.spec_gamma, scfg.catch_chunk or 0)
        self.extend_ok = bool(M.extendable(cfg)
                              and self.K >= 2
                              and (cfg.pattern_period <= 1
                                   or self.K <= W))
        # Sarathi-style admission: prompts become wave spans (pending
        # catch-up from position 0 / the prefix-hit frontier) instead
        # of a blocking bucketed prefill.  Recurrent families with no
        # extend wave still honour the flag — their catch-up rides the
        # plain decode wave one token per step.
        self.chunked = bool(scfg.chunked_prefill)
        # speculative decoding: draft model + acceptance loop.  Engages
        # only where a rejected run can roll back exactly
        # (model.spec_decodable — mirrors the prefix_cache gate);
        # incompatible draft/gamma is a configuration ERROR.
        self.spec = None
        if scfg.spec_decode and M.spec_decodable(cfg):
            from repro.serving.spec_decode import (SpecDecoder,
                                                   make_self_draft,
                                                   validate_spec)
            if draft is not None:
                dcfg, dparams = draft
            elif scfg.draft_arch in (None, "self"):
                if scfg.quant_draft:
                    # the self-draft trunk IS the verify trunk (shared
                    # by reference) — quantizing it would materialise a
                    # private copy, the opposite of saving draft bytes
                    raise ValueError(
                        "quant_draft requires a separate draft model "
                        "(draft_arch or an explicit draft); the "
                        "early-exit self-draft shares the verify trunk "
                        "by reference")
                dcfg, dparams = make_self_draft(
                    cfg, params, key=jax.random.PRNGKey(scfg.seed))
            else:
                from repro.configs import get_smoke_config
                dcfg = get_smoke_config(scfg.draft_arch)
                dparams = M.init_params(dcfg,
                                        jax.random.PRNGKey(scfg.seed))
            problems = validate_spec(cfg, dcfg, scfg.spec_gamma, T)
            if problems:
                raise ValueError("spec_decode misconfigured: "
                                 + "; ".join(problems))
            if scfg.quant_draft:
                from repro.models.layers import quantize_matmul_params
                dparams = quantize_matmul_params(dparams)
            self.spec = SpecDecoder(dcfg, dparams, B, T)
        elif scfg.quant_draft and not scfg.spec_decode:
            raise ValueError("quant_draft without spec_decode: there is "
                             "no draft model to quantize")
        self.tokens = np.zeros((B, 1), np.int32)
        self.pos = np.zeros((B,), np.int32)
        self.temps = np.zeros((B,), np.float32)
        self.topks = np.zeros((B,), np.int32)
        self.active = np.zeros((B,), bool)
        self.slot_req: list[Optional[Request]] = [None] * B
        self.pending: list[Optional[np.ndarray]] = [None] * B
        self.queue: list[Request] = []
        self._key = jax.random.PRNGKey(scfg.seed)
        self._rng = np.random.default_rng(scfg.seed)   # admission sampling
        self._arrival = itertools.count()
        # specialized on the static any_topk flag: the all-greedy /
        # temperature-only path must not pay an O(B·V log V) vocab sort
        # per decoded token (at most two variants ever compile).
        # The cache buffers are DONATED: decode rewrites the KV state
        # in place instead of allocating a second copy every step,
        # halving decode HBM traffic (a no-op where the backend cannot
        # alias, e.g. CPU).
        self._decode = jax.jit(self._decode_fn,
                               static_argnames=("any_topk",),
                               donate_argnums=(1,))
        # per-pool-leaf page copy for copy-on-write forks (cache donated:
        # the fork rewrites one page in place, not a second pool copy)
        self._copy_page = (jax.jit(self._copy_page_fn, donate_argnums=(0,))
                           if self.paged else None)
        # multi-token extend wave (width spec_gamma static; at most two
        # variants compile — with and without the full-logits return)
        self._extend = (jax.jit(self._extend_fn, donate_argnums=(1,),
                                static_argnames=("need_logits",))
                        if self.extend_ok else None)
        self._prefills: dict[tuple, Callable] = {}
        self.steps = 0
        self.completed: list[Request] = []
        self.cancelled: list[Request] = []
        # step-driven observability: the last wave's per-slot plan
        # (mode, width) and how often prompt chunks actually interleave
        # with decode/spec slots in one wave
        self.last_plan: dict[int, tuple] = {}
        self.mixed_waves = 0
        self.wave_admitted = 0      # requests admitted as wave spans
        self.cancels = 0
        # observability: paged-admission effectiveness + pressure events
        self.peak_active = 0
        self.peak_pool_used = 0
        self.exhaust_preempts = 0
        self.reclaims = 0
        self.cow_forks = 0
        # speculative-decoding counters: rounds = (slot, wave) drafting
        # participations; proposed/accepted per round; emitted includes
        # the per-round correction/bonus token
        self.spec_steps = 0
        self.spec_rounds = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        # telemetry: the registry is ALWAYS on (stats() below is a
        # compatibility view over it); the tracer only with
        # ServeConfig.trace.  Counters stay plain attributes —
        # benchmarks/tests reset them by assignment (`eng.steps = 0`) —
        # and the registry reads them through callback gauges.
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(clock=scfg.trace_clock) if scfg.trace else None
        self._legacy_stats = self._register_metrics()

    @property
    def _prefix(self) -> int:
        return self.cfg.num_image_tokens if self.cfg.family == "vlm" else 0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def reset_rng(self) -> None:
        """Re-seed the sampling state (device PRNG key + admission rng)
        to the ServeConfig seed.  Benchmarks call this after a warmup
        pass so a temperature>0 measured run samples the same tokens a
        cold engine would — replay determinism."""
        self._key = jax.random.PRNGKey(self.scfg.seed)
        self._rng = np.random.default_rng(self.scfg.seed)

    def submit(self, req: Request) -> None:
        limit = self.scfg.max_len - 1 - self._prefix
        if req.saved_state is None:
            if len(req.prompt) > limit:
                raise ValueError(
                    f"prompt length {len(req.prompt)} exceeds max_len "
                    f"budget {limit} (max_len={self.scfg.max_len})")
            worst = self._prefix + len(req.prompt) + req.max_new_tokens
        else:
            st = req.saved_state
            pend = st.get("pending")
            n_pend = 0 if pend is None else int(np.size(pend))
            if len(req.generated) >= req.max_new_tokens:
                raise ValueError(
                    f"resumed request {req.uid} already generated "
                    f"{len(req.generated)}/{req.max_new_tokens} tokens — "
                    "nothing left to decode")
            if int(st["pos"]) + n_pend >= self.scfg.max_len - 1:
                raise ValueError(
                    f"resumed request {req.uid} cannot make progress: "
                    f"pos {int(st['pos'])} + pending {n_pend} >= "
                    f"max_len-1 ({self.scfg.max_len - 1}); it would burn "
                    "a prefill-free slot and finish with zero new tokens")
            worst = (int(st["pos"]) + n_pend + 1
                     + req.max_new_tokens - len(req.generated))
        if self.paged:
            need = blocks_for_tokens(min(worst, self.scfg.max_len),
                                     self.block_size)
            if need > self.pool.num_blocks:
                raise ValueError(
                    f"request {req.uid} may need {need} KV blocks but the "
                    f"pool holds only {self.pool.num_blocks} "
                    f"(kv_pool_blocks); it could never finish")
        if req.arrival is None:
            req.arrival = float(next(self._arrival))
        if self.tracer is not None:
            fresh = req.saved_state is None and not req.generated
            self._revent(req, "submit" if fresh else "resubmit",
                         prompt_tokens=len(req.prompt))
            self._revent(req, "queued", depth=len(self.queue) + 1)
        self.queue.append(req)

    def _rank(self, req: Request):
        from repro.core.scheduler import admission_rank
        return admission_rank(self.scfg.policy, priority=req.priority,
                              arrival=req.arrival, deadline=req.deadline,
                              uid=req.uid)

    def _bucket(self, n: int) -> int:
        for b in self.scfg.prefill_buckets:
            if n <= b:
                return b
        return self.scfg.prefill_buckets[-1]

    def _prefill_fn(self, bucket: int, m: int, extras_sig: tuple,
                    n_ctx: int):
        """Jitted fused admission prefill, cached per (bucket, batch,
        extras, ctx-width) — prompt K/V is written straight into the
        engine cache (pages + slot rows) in the same call, and the
        cache buffers are donated so admission updates them in place.

        ``n_ctx``: static width (in blocks) of the shared-prefix FULL
        tables (context + write span in one view — token-granular hits
        write mid-page through the same table they read); 0 compiles
        the cold no-context variant.
        """
        key = (bucket, m, extras_sig, n_ctx, self.paged)
        if key not in self._prefills:
            cfg, scfg, paged = self.cfg, self.scfg, self.paged

            if n_ctx:
                def fn(params, batch, true_len, cache, slots,
                       full_tables, ctx_len):
                    return M.prefill_paged(
                        cfg, params, batch, scfg.max_len, cache,
                        slots=slots, write_tables=full_tables,
                        ctx_tables=full_tables, ctx_len=ctx_len,
                        true_len=true_len)
            elif paged:
                def fn(params, batch, true_len, cache, slots,
                       write_tables):
                    return M.prefill_paged(
                        cfg, params, batch, scfg.max_len, cache,
                        slots=slots, write_tables=write_tables,
                        true_len=true_len)
            else:
                def fn(params, batch, true_len, cache, slots):
                    return M.prefill_paged(
                        cfg, params, batch, scfg.max_len, cache,
                        slots=slots, true_len=true_len)

            self._prefills[key] = jax.jit(fn, donate_argnums=(3,))
        return self._prefills[key]

    def _sample_first(self, req: Request, logits: np.ndarray) -> int:
        """First generated token, from the admission logits (host-side,
        engine-rng — deterministic for a fixed ServeConfig.seed)."""
        from repro.serving.spec_decode import sample_from_logits
        temp = (self.scfg.temperature if req.temperature is None
                else req.temperature)
        top_k = self.scfg.top_k if req.top_k is None else req.top_k
        return sample_from_logits(logits, temp, top_k, self._rng)

    # -- prefix-cache keys ---------------------------------------------
    def _key_ns(self, req: Request) -> int:
        """Namespace digest for non-token inputs: requests whose K/V
        depends on more than the token ids (VLM images, enc-dec audio)
        only ever share with requests carrying identical extras.
        Memoized on the request — extras are immutable for its
        lifetime, and ``_publish_frontiers`` asks once per page
        crossing (hashing a VLM image tensor per wave would be pure
        rework on the decode loop)."""
        ns = getattr(req, "_ns_digest", None)
        if ns is not None:
            return ns
        if not req.extras:
            ns = 0
        else:
            h = hashlib.sha1()
            for k in sorted(req.extras):
                h.update(k.encode())
                h.update(np.ascontiguousarray(req.extras[k]).tobytes())
            ns = int.from_bytes(h.digest()[:8], "little") & (2 ** 63 - 1)
        req._ns_digest = ns
        return ns

    def _key_tokens(self, req: Request) -> np.ndarray:
        """Logical token sequence whose positions map 1:1 onto the
        slot's pages: VLM image positions become pseudo-tokens (the
        namespace digest already pins the image identity), then the
        prompt, then tokens generated so far (KV-valid prefix of it is
        taken by the caller)."""
        parts = [np.full((self._prefix,), -42, np.int64),
                 np.asarray(req.prompt, np.int64)]
        folded = getattr(req, "_folded_generated", 0)
        if len(req.generated) > folded:
            parts.append(np.asarray(req.generated[folded:], np.int64))
        return np.concatenate(parts)

    def _lookup(self, req: Request) -> None:
        """Radix lookup for a fresh request: acquire (incref) the
        longest usable shared chain — TOKEN-granular, possibly ending
        mid-page and possibly inside a chain another slot is still
        decoding — and stash it on the request for the admission pass.
        Capped at one token short of the prompt (the suffix prefill
        must produce admission logits) and — for VLM — at least the
        image prefix (a shorter match cannot seed a text-only suffix
        prefill)."""
        self._release_ctx(req)          # drop any stale acquisition
        if not self.sharable or req.saved_state is not None:
            return
        key = np.concatenate([np.full((self._prefix,), -42, np.int64),
                              np.asarray(req.prompt, np.int64)])
        blocks, n = self.prefix_cache.match(
            key, namespace=self._key_ns(req), max_tokens=len(key) - 1)
        if n and n < self._prefix:
            self.pool.free(blocks)
            self.prefix_cache.unrecord_hit(
                len(blocks), n, (n // self.block_size) * self.block_size)
            blocks, n = [], 0
        req._ctx_blocks = blocks
        req._ctx_len = n

    def _release_ctx(self, req: Request) -> None:
        """Drop an acquired-but-unused shared chain (request skipped by
        this admission round; the next round re-acquires) — and roll
        the hit accounting back so retries don't inflate the stats."""
        blocks = getattr(req, "_ctx_blocks", None)
        if blocks:
            n = req._ctx_len
            self.pool.free(blocks)
            self.prefix_cache.unrecord_hit(
                len(blocks), n, (n // self.block_size) * self.block_size)
        req._ctx_blocks, req._ctx_len = [], 0

    # -- paged-pool bookkeeping ----------------------------------------
    def _first_span(self, req: Request, suffix_len: int) -> int:
        """Tokens the request's FIRST admission step covers: the full
        bucketed prefill normally; under chunked_prefill just the first
        wave span (the extend chunk width, or one decode token on
        recurrent families) — extras-carrying requests still prefill,
        but only the smallest bucket."""
        if self.chunked:
            if req.extras:
                return min(suffix_len, self.scfg.prefill_buckets[0])
            return min(suffix_len, self.K if self.extend_ok else 1)
        return min(suffix_len, self.scfg.prefill_buckets[-1])

    def _blocks_needed(self, req: Request) -> int:
        """New pool blocks this request needs to be admitted NOW (the
        first admission span's pages + one covering the next write;
        resumed requests already hold pages for [0, pos), prefix-cache
        hits already hold the shared chain's pages).  Chunked-prefill
        admission reserves only the first wave's span — later chunks
        allocate wave by wave (preempt-or-queue backstops a pool that
        fills in between)."""
        if not self.paged:
            return 0
        bs = self.block_size
        if req.saved_state is not None:
            held = len(req.saved_state.get("blocks", ()))
            return max(0, blocks_for_tokens(
                int(req.saved_state["pos"]) + 1, bs) - held)
        L = getattr(req, "_ctx_len", 0)
        if L:
            # token-granular hits: floor(L/bs) pages are shared whole;
            # a partial final page (L % bs != 0) is counted as NEEDED
            # because admission CoW-forks it (the fork's alloc draws
            # one page from the free list)
            suffix = len(req.prompt) - (L - self._prefix)
            n1 = self._first_span(req, suffix)
            return blocks_for_tokens(L + n1 + 1, bs) - L // bs
        n1 = self._first_span(req, len(req.prompt))
        return blocks_for_tokens(self._prefix + n1 + 1, bs)

    def _reserve(self, n: int) -> bool:
        """Make ``n`` pool pages allocatable, evicting LRU prefix-cache
        chains if the free list alone is short."""
        if not self.paged:
            return True
        short = n - self.pool.num_free
        if short > 0 and self.prefix_cache is not None:
            self.prefix_cache.evict(short)
        return self.pool.num_free >= n

    def _avail_blocks(self) -> int:
        """Pages admission may count on: free now + evictable now."""
        if not self.paged:
            return 0
        extra = (self.prefix_cache.evictable_blocks()
                 if self.prefix_cache is not None else 0)
        return self.pool.num_free + extra

    def _set_table(self, slot: int, blocks: list[int]) -> None:
        self.slot_blocks[slot] = blocks
        self.block_tables[slot, :] = -1
        self.block_tables[slot, :len(blocks)] = blocks

    def _place(self, req: Request, slot: int) -> None:
        """Common slot bookkeeping after cache insertion."""
        self.temps[slot] = (self.scfg.temperature if req.temperature is None
                            else req.temperature)
        self.topks[slot] = self.scfg.top_k if req.top_k is None else req.top_k
        self.active[slot] = True
        self.slot_req[slot] = req

    def _admit_resumed(self, req: Request, slot: int) -> None:
        need = self._blocks_needed(req)   # same formula the scan reserved
        self._slot_begin(req, slot)
        self._revent(req, "resume", slot=slot)
        st = req.saved_state
        req.saved_state = None
        if self.paged:
            blocks = list(st.get("blocks", ()))
            if need:  # feasibility pre-checked by the admission scan
                self._reserve(need)
                blocks += self.pool.alloc(need)
            self._set_table(slot, blocks)
        self.cache = insert_slot(self.cache, st["cache"], slot, self.axes)
        if self.spec is not None:
            self.spec.insert(slot, st.get("draft"))
        self.pos[slot] = st["pos"]
        self.tokens[slot, 0] = st["last_tok"]
        self.pending[slot] = st["pending"]
        self._place(req, slot)
        # resume in-flight publication where the preempted slot left it
        # (re-publishing would only dedup, but skip the wasted walks)
        self.slot_published[slot] = int(st.get("published", 0))

    def _admit_wave(self, req: Request, slot: int) -> None:
        """Chunked-prefill admission: NO prefill call — the prompt (or
        the unmatched suffix after a radix hit) becomes the slot's
        pending span and is consumed through the same decode/extend
        waves every other slot rides, ``_first_span`` tokens per wave.
        Shared context pages attach exactly as the prefill path would;
        the first wave's ``_ensure_blocks``/``_cow_guard`` allocate
        fresh pages and CoW-fork a partially-matched tail page on
        demand.  The first generated token is sampled from the wave row
        that consumes the last prompt token (the existing catch-up
        retirement), so admission never blocks in-flight decoders."""
        L = getattr(req, "_ctx_len", 0)
        self._slot_begin(req, slot)
        self._revent(req, "admitted", slot=slot, mode="wave",
                     prefix_hit_tokens=L)
        if self.paged:
            ctx = getattr(req, "_ctx_blocks", None) or []
            self._set_table(slot, list(ctx))
        req._ctx_blocks, req._ctx_len = [], 0
        suffix = np.asarray(req.prompt, np.int32)[max(0, L - self._prefix):]
        if self.spec is not None:
            # the draft still prefills the full prompt (it is cheap and
            # never chunks) so the slot is draft-complete by the time
            # its prompt is consumed — same contract as bucketed
            # catch-up admission
            self.spec.admit_group([req], [slot])
        self.pos[slot] = L
        self.tokens[slot, 0] = int(suffix[0])
        self.pending[slot] = suffix[1:]
        self._place(req, slot)
        # the matched prefix is already indexed (that is what we hit) —
        # publication resumes from its page boundary
        self.slot_published[slot] = (L // self.block_size
                                     * self.block_size)
        self.wave_admitted += 1

    @staticmethod
    def _pow2(n: int) -> int:
        return 1 << (n - 1).bit_length() if n > 1 else n

    def _admit_batch(self) -> None:
        """Admit queued requests into free slots, batching prefill per
        (bucket, extras, shared-context width) group — one compile +
        one device call per group.

        Capacity-aware: a request is taken only if the pool can cover
        its suffix pages + first decode write, counting radix-cache
        pages evictable right now as available.  Fresh requests are
        looked up in the prefix cache first: a hit pins the shared
        chain (incref) and shrinks both the pages needed and the
        prefill to the unmatched suffix.  Requests that don't fit right
        now are skipped, NOT dropped — they wait for pages to free
        (best-effort packing under memory pressure; admission order
        within the feasible set still follows admission_rank)."""
        if not self.queue:
            return
        free = [s for s in range(self.scfg.max_slots) if not self.active[s]]
        if not free:
            return
        self.queue.sort(key=self._rank)
        avail = self._avail_blocks()
        taken, kept = [], []
        for req in self.queue:
            if not free:
                kept.append(req)
                continue
            self._lookup(req)
            need = self._blocks_needed(req)
            # pinning a hit chain removes its pages from the evictable
            # set, so they count against this round's budget too — but
            # ONLY pages this lookup actually pinned (refcount exactly
            # 2 = cache + us; pages another reader already pins were
            # never in the evictable count)
            pinned = sum(1 for b in (getattr(req, "_ctx_blocks", None)
                                     or ())
                         if self.pool.refcount(b) == 2)
            if self.paged and need + pinned > avail:
                self._release_ctx(req)
                kept.append(req)
                continue
            avail -= need + pinned
            taken.append((req, free.pop(0)))
        self.queue = kept

        fresh: dict[tuple, list] = {}   # group key -> [(req, slot)]
        for req, slot in taken:
            if req.saved_state is not None:
                self._admit_resumed(req, slot)
                continue
            if self.chunked and not req.extras:
                self._admit_wave(req, slot)
                continue
            L = getattr(req, "_ctx_len", 0)
            n1 = self._first_span(
                req, len(req.prompt) - max(0, L - self._prefix))
            bucket = self._bucket(n1)
            sig = tuple(sorted(
                (k, np.asarray(v).shape) for k, v in req.extras.items()))
            # hit rows read AND write through one full table covering
            # [0, L + n1) — pow2-bucketed so mixed-depth hits share a
            # compile
            n_ctx = (self._pow2(blocks_for_tokens(L + n1, self.block_size))
                     if L else 0)
            fresh.setdefault((bucket, sig, n_ctx), []).append((req, slot))

        for (bucket, sig, n_ctx), group in fresh.items():
            self._admit_group(bucket, sig, n_ctx, group)

    def _admit_group(self, bucket: int, extras_sig: tuple, n_ctx: int,
                     group) -> None:
        """One fused admission call: batched (suffix-)prefill that
        writes prompt K/V straight into pages + slot rows.  ``n_ctx``
        > 0 means every row is a prefix-cache hit admitted at its
        shared chain's end position — which, with token-granular
        matching, may be MID-page: the partial page is CoW-forked here
        (private copy) so the suffix write never lands in a page the
        cache or another reader still holds."""
        bs = self.block_size
        if self.paged:
            # allocation pass first: a row whose pages cannot be
            # covered even after eviction (a chain pinned mid-scan ate
            # the budget) goes back to the queue instead of raising
            admitted = []
            for req, slot in group:
                need = self._blocks_needed(req)
                L = getattr(req, "_ctx_len", 0)
                try:
                    self._reserve(need)
                    fresh_n = need
                    if L % bs:
                        # fork the partially-matched final page: trade
                        # the reader's ref on the shared page for a
                        # private copy the suffix may write into.
                        # `need` already counts this page, so the fresh
                        # alloc shrinks by one either way: normally the
                        # fork draws that page itself (cache + reader
                        # refs), and if the cache released its ref
                        # mid-scan (a retire upgraded the tail) fork
                        # hands back the now-private page with no
                        # allocation at all.
                        fresh_n = need - 1
                        old = req._ctx_blocks[-1]
                        new = self.pool.fork(old)
                        if new != old:
                            self.cache = self._copy_page(
                                self.cache, jnp.asarray(old),
                                jnp.asarray(new))
                            req._ctx_blocks[-1] = new
                            self.cow_forks += 1
                            self._revent(req, "cow_fork", slot=slot)
                    fresh_alloc = self.pool.alloc(fresh_n)
                except PoolExhausted:
                    self._release_ctx(req)
                    self.queue.append(req)
                    continue
                ctx = getattr(req, "_ctx_blocks", None) or []
                self._set_table(slot, list(ctx) + fresh_alloc)
                admitted.append((req, slot))
            group = admitted
            if not group:
                return
        for req, slot in group:
            self._slot_begin(req, slot)
            self._revent(req, "admitted", slot=slot, mode="prefill",
                         prefix_hit_tokens=getattr(req, "_ctx_len", 0))
        m = len(group)
        prompts = np.zeros((m, bucket), np.int32)
        true_len = np.zeros((m,), np.int32)
        ctx_len = np.zeros((m,), np.int32)
        # hit rows: ONE full table per row (context + write span, from
        # logical block 0) — reads mask below ctx_len, writes scatter
        # from ctx_len; cold rows: a write-span table from block 0
        # including the VLM image prefix
        span = self._prefix + bucket
        n_wblk = n_ctx if n_ctx else blocks_for_tokens(span, bs)
        tables = np.full((m, n_wblk), -1, np.int32)
        suffixes = []
        for i, (req, slot) in enumerate(group):
            L = getattr(req, "_ctx_len", 0)
            suffix = np.asarray(req.prompt, np.int32)[max(0, L - self._prefix):]
            suffixes.append(suffix)
            n1 = min(len(suffix), bucket)
            # pad value is irrelevant (true_len masks it) — repeat last tok
            prompts[i] = suffix[n1 - 1]
            prompts[i, :n1] = suffix[:n1]
            true_len[i] = n1
            ctx_len[i] = L
            if self.paged:
                blk = self.slot_blocks[slot][:n_wblk]
                tables[i, :len(blk)] = blk
        batch = {"tokens": jnp.asarray(prompts)}
        for k, _ in extras_sig:
            batch[k] = jnp.asarray(
                np.stack([np.asarray(r.extras[k]) for r, _ in group]))
        slots_arr = jnp.asarray([s for _, s in group], jnp.int32)
        args = [self.params, batch, jnp.asarray(true_len), self.cache,
                slots_arr]
        if self.paged:
            args.append(jnp.asarray(tables))
        if n_ctx:
            args.append(jnp.asarray(ctx_len))
        with self._span("prefill_dispatch", bucket=bucket, rows=m):
            logits, self.cache = self._prefill_fn(bucket, m, extras_sig,
                                                  n_ctx)(*args)
        if self.tracer is not None:
            # value-neutral fence: device prefill time vs the host
            # first-token sampling loop below
            with self.tracer.span("prefill_sync"):
                jax.block_until_ready(logits)
        if self.spec is not None:
            # the draft prefills the FULL prompt (it is cheap and never
            # chunks), so catch-up slots are already draft-complete by
            # the time their prompt is consumed
            self.spec.admit_group([r for r, _ in group],
                                  [s for _, s in group])
        logits_host = np.asarray(logits[:, -1], np.float32)   # (m, V)
        for i, (req, slot) in enumerate(group):
            L = int(ctx_len[i])
            n1 = int(true_len[i])
            req._ctx_blocks, req._ctx_len = [], 0
            remainder = suffixes[i][n1:]
            self._revent(req, "prefill_chunk", slot=slot, n=n1)
            tok = None
            if not remainder.size:
                self._revent(req, "prompt_done", slot=slot)
                tok = self._sample_first(req, logits_host[i])
                req.generated.append(tok)
                self._rtokens(req, slot, 1)
                hit_eos = (self.scfg.eos_id >= 0
                           and tok == self.scfg.eos_id)
                if len(req.generated) >= req.max_new_tokens or hit_eos:
                    # the admission token already completed the request
                    # — it never occupies a slot or a decode step, but
                    # its pages DO hold a fully valid chain: index it
                    if self.paged:
                        n_valid = (L if L else self._prefix) + n1
                        self._retire_chain(req, self.slot_blocks[slot],
                                           n_valid)
                        self._set_table(slot, [])
                    req.done = True
                    self.completed.append(req)
                    self._revent(req, "finish", slot=slot,
                                 n_generated=len(req.generated))
                    self._slot_end(slot)
                    continue
            self.pos[slot] = (L if L else self._prefix) + n1
            if remainder.size:
                # chunked prefill: catch up through the decode wave
                self.pending[slot] = remainder[1:]
                self.tokens[slot, 0] = int(remainder[0])
            else:
                self.pending[slot] = None
                self.tokens[slot, 0] = tok
            self._place(req, slot)
            # the matched prefix is already indexed (that is what we
            # hit) — publication resumes from its page boundary
            self.slot_published[slot] = (L // self.block_size
                                         * self.block_size)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _decode_fn(self, params, cache, tokens, pos, temps, topks, key,
                   block_tables=None, any_topk: bool = False):
        if block_tables is None:
            logits, new_cache = M.decode_step(self.cfg, params, cache,
                                              tokens, pos)
        else:
            logits, new_cache = M.decode_step_paged(
                self.cfg, params, cache, tokens, pos, block_tables,
                self.scfg.use_pallas_paged)
        logits = logits[:, -1, :].astype(jnp.float32)          # (B, V)
        greedy = jnp.argmax(logits, axis=-1)
        masked = logits
        if any_topk:
            V = logits.shape[-1]
            desc = jnp.sort(logits, axis=-1)[:, ::-1]
            kth = jnp.take_along_axis(
                desc, jnp.clip(topks - 1, 0, V - 1)[:, None], axis=1)
            masked = jnp.where((topks > 0)[:, None] & (logits < kth),
                               -jnp.inf, logits)
        scaled = masked / jnp.maximum(temps, 1e-6)[:, None]
        sampled = jax.random.categorical(key, scaled, axis=-1)
        nxt = jnp.where(temps > 0, sampled, greedy)
        return nxt.astype(jnp.int32), new_cache

    def _extend_fn(self, params, cache, tokens, pos, valid, block_tables,
                   need_logits: bool = False):
        """Multi-token wave: score ``spec_gamma`` tokens per slot in one
        call (speculative verify / chunked catch-up).  Acceptance and
        sampling are host-side with the engine rng — which keeps greedy
        spec bit-identical to vanilla (argmax is rounding-free) and
        rejection sampling deterministic per seed — but an all-greedy
        wave ships only the (B, K) per-row argmax ids; the full
        (B, K, V) float32 logits cross the device boundary only when
        some active slot samples at temperature > 0 (at real vocab
        sizes that transfer dominates the wave)."""
        if block_tables is None:
            logits, new_cache = M.extend(self.cfg, params, cache, tokens,
                                         pos, valid)
        else:
            logits, new_cache = M.extend_paged(self.cfg, params, cache,
                                               tokens, pos, block_tables,
                                               valid,
                                               self.scfg.use_pallas_paged)
        logits = logits.astype(jnp.float32)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy, (logits if need_logits else None), new_cache

    def _ensure_blocks(self, spans: Optional[dict] = None) -> None:
        """Guarantee every active slot's table covers its write span
        ``[pos, pos + span)`` (span 1 = plain decode; an extend wave
        passes its per-slot widths).  Crossing block boundaries appends
        pages (evicting LRU prefix-cache chains first under pressure);
        if the pool is truly exhausted the slot is preempted back to
        the queue (pages detached) — preempt-or-queue, never a deadlock
        spin.  Best-ranked slots get first pick of the remaining pages.
        """
        bs = self.block_size
        spans = spans or {}
        needy = []
        for s in range(self.scfg.max_slots):
            if not self.active[s]:
                continue
            target = blocks_for_tokens(
                int(self.pos[s]) + spans.get(s, 1), bs)
            if target > len(self.slot_blocks[s]):
                needy.append((s, target))
        needy.sort(key=lambda t: self._rank(self.slot_req[t[0]]))
        for s, target in needy:
            n = target - len(self.slot_blocks[s])
            try:
                self._reserve(n)
                blk = self.pool.alloc(n)
            except PoolExhausted:
                req = self.preempt(s)
                self.exhaust_preempts += 1
                self.queue.append(req)   # resumes when a page frees
                continue
            j0 = len(self.slot_blocks[s])
            self.slot_blocks[s].extend(blk)
            self.block_tables[s, j0:j0 + n] = blk

    def _copy_page_fn(self, cache, src, dst):
        """Device-side page copy (every pool leaf) for CoW forks."""
        return jax.tree.map(
            lambda leaf, ax: leaf if ax >= 0 else
            leaf.at[:, dst].set(leaf[:, src]),
            cache, self.axes)

    def _cow_guard(self, spans: Optional[dict] = None) -> None:
        """Copy-on-write backstop: no decode/extend wave may write a
        page with more than one owner — a speculative verify over a
        shared prefix-cache chain must fork, never scribble into a
        reader's pages.  Block-granular prefix matching means the write
        span normally lands in private pages (suffixes start at the
        next block boundary), so this almost never fires — but any
        sharer of a TAIL page (token-granular matching, beam forks, a
        spec round whose span begins mid-shared-block) is caught here:
        the slot trades its reference for a fresh page
        (``KVBlockPool.fork``) and copies the page bytes.  On pool
        exhaustion the slot preempts, like ``_ensure_blocks``.
        """
        bs = self.block_size
        spans = spans or {}
        for s in range(self.scfg.max_slots):
            if not self.active[s]:
                continue
            j0 = int(self.pos[s]) // bs
            j1 = min((int(self.pos[s]) + spans.get(s, 1) - 1) // bs,
                     len(self.slot_blocks[s]) - 1)
            for j in range(j0, j1 + 1):
                old = self.slot_blocks[s][j]
                if self.pool.refcount(old) <= 1:
                    continue
                try:
                    self._reserve(1)
                    new = self.pool.fork(old)
                except PoolExhausted:
                    req = self.preempt(s)
                    self.exhaust_preempts += 1
                    self.queue.append(req)
                    break
                self.cache = self._copy_page(self.cache, jnp.asarray(old),
                                             jnp.asarray(new))
                self.slot_blocks[s][j] = new
                self.block_tables[s, j] = new
                self.cow_forks += 1
                self._revent(self.slot_req[s], "cow_fork", slot=s)

    def _has_pending(self) -> bool:
        return any(self.active[s] and self.pending[s] is not None
                   and self.pending[s].size
                   for s in range(self.scfg.max_slots))

    def _apply_budget(self, plan: dict) -> dict:
        """Wave-token budget: shrink catch-up / speculative widths so
        the wave's total fed tokens fit ``ServeConfig.wave_tokens``,
        granting best-QoE-rank first (``core.scheduler.plan_wave``;
        every slot keeps width >= 1 — liveness).  Width is a pure
        schedule lever: shrinking a span never changes the tokens a
        request emits, so QoE shaping here cannot cause token drift."""
        if self.scfg.wave_tokens is None or not plan:
            return plan
        from repro.core.scheduler import plan_wave
        entries = []
        for s, (mode, want) in plan.items():
            r = self.slot_req[s]
            entries.append({"id": s, "want": want, "priority": r.priority,
                            "arrival": r.arrival, "deadline": r.deadline,
                            "uid": r.uid})
        widths = plan_wave(self.scfg.policy, entries,
                           self.scfg.wave_tokens, metrics=self.metrics)
        out = {}
        for s, (mode, want) in plan.items():
            v = min(want, widths[s])
            if mode == "spec" and v < 2:
                # a 1-wide speculative round is just a decode
                mode, v = "plain", 1
            out[s] = (mode, v)
        return out

    def _record_plan(self, plan: dict) -> None:
        """Wave-plan observability: keep the committed plan
        (``last_plan``, read by ``scripts/diagnose.py --server``) and
        count waves where a prompt chunk actually interleaved with a
        decoding/speculating slot — the Sarathi property the open-loop
        benchmark gates on."""
        self.last_plan = dict(plan)
        modes = {m for m, _ in plan.values()}
        if "catch" in modes and len(modes) > 1:
            self.mixed_waves += 1

    def step(self) -> int:
        """ONE step of the always-on serving core — no drain
        assumption; an asyncio frontend (``launch.serve``) calls this
        forever, interleaving arrivals and cancellations between waves:

        * **admit** — rank the queue (``admission_rank``), place what
          fits (capacity-aware); under ``chunked_prefill`` a prompt
          becomes a pending wave span instead of a blocking bucketed
          prefill (``_admit_wave``);
        * **plan** — pick the wave type (multi-token extend while any
          slot speculates or catches up, one-token decode otherwise)
          and per-slot widths, budgeted by ``wave_tokens``
          (``_apply_budget`` -> ``core.scheduler.plan_wave``);
        * **wave** — ONE jitted device call for every active slot;
        * **retire** — sample/accept per slot, finish on EOS / budget /
          room, publish in-flight prefix frontiers.

        Pool-wedge recovery is part of the step contract: when nothing
        stepped but requests are queued and every page is held by
        detached (preempted) requests, the worst-ranked holder is
        force-reclaimed so an always-on loop cannot spin idle.  Returns
        the number of active slots stepped (0 = idle).
        """
        with self._span("step", step=self.steps):
            with self._span("admit", queued=len(self.queue)):
                self._admit_batch()
            if self.extend_ok and (self.spec is not None
                                   or self._has_pending()):
                stepped = self._extend_step()
            else:
                stepped = self._decode_wave()
            if (stepped == 0 and self.paged and self.queue
                    and not self.active.any()):
                # requests requeued by _ensure_blocks mid-step (after
                # this step's admission pass) may need zero new pages —
                # give admission one more look before reclaiming
                with self._span("admit", queued=len(self.queue)):
                    self._admit_batch()
                if not self.active.any():
                    # every queued request is blocked on pool pages held
                    # by detached requests: force-reclaim the worst one
                    self._reclaim()
        return stepped

    def _decode_wave(self) -> int:
        """The plain one-token wave: plan is implicit (every active
        slot has width 1; slots still consuming a prompt on a
        non-extendable family teacher-force one pending token)."""
        with self._span("plan"):
            if self.paged:
                self._ensure_blocks()
                self._cow_guard()
            self._record_plan({
                s: (("catch", 1) if (self.pending[s] is not None
                                     and self.pending[s].size) else
                    ("plain", 1))
                for s in range(self.scfg.max_slots) if self.active[s]})
        n_active = int(self.active.sum())
        if n_active == 0:
            return 0
        self.peak_active = max(self.peak_active, n_active)
        if self.paged:
            self.peak_pool_used = max(self.peak_pool_used,
                                      self.pool.num_used)

        self._key, sub = jax.random.split(self._key)
        any_topk = bool((self.topks[self.active] > 0).any())
        tables = (jnp.asarray(self.block_tables) if self.paged else None)
        with self._span("dispatch", mode="decode", rows=n_active):
            nxt, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self.tokens),
                jnp.asarray(self.pos), jnp.asarray(self.temps),
                jnp.asarray(self.topks), sub, tables, any_topk=any_topk)
        if self.tracer is not None:
            # value-neutral fence: splits device time ("sync") from the
            # host sampling/retire loop below — tokens are untouched
            with self.tracer.span("sync"):
                jax.block_until_ready(nxt)
        nxt_host = np.asarray(nxt)
        with self._span("retire"):
            for slot in range(self.scfg.max_slots):
                if not self.active[slot]:
                    continue
                self.pos[slot] += 1
                req = self.slot_req[slot]
                pend = self.pending[slot]
                out_of_room = int(self.pos[slot]) >= self.scfg.max_len - 1
                if pend is not None and pend.size:
                    # still consuming the prompt: teacher-force the next
                    # prompt token, discard the sampled one
                    self._revent(req, "prefill_chunk", slot=slot, n=1)
                    self.tokens[slot, 0] = int(pend[0])
                    self.pending[slot] = pend[1:]
                    if out_of_room:
                        self._finish(slot, req)
                    continue
                if pend is not None:
                    # the wave that consumed the last prompt token
                    self._revent(req, "prompt_done", slot=slot)
                self.pending[slot] = None
                tok = int(nxt_host[slot])
                self.tokens[slot, 0] = tok
                req.generated.append(tok)
                self._rtokens(req, slot, 1)
                hit_eos = (self.scfg.eos_id >= 0
                           and tok == self.scfg.eos_id)
                if (len(req.generated) >= req.max_new_tokens or hit_eos
                        or out_of_room):
                    self._finish(slot, req)
        with self._span("publish"):
            self._publish_frontiers()
        self.steps += 1
        return n_active

    def _truncate_slot(self, slot: int) -> None:
        """KV rollback: free the slot's pages past its write frontier
        (block-boundary granular).  After a rejected speculation the
        stale K/V above ``pos`` is already invisible (the extend/decode
        context masks strictly below the frontier), so rollback is pure
        bookkeeping — return whole tail pages, keep the partial one the
        next write lands in."""
        if not self.paged:
            return
        keep = blocks_for_tokens(int(self.pos[slot]) + 1, self.block_size)
        blocks = self.slot_blocks[slot]
        if len(blocks) > keep:
            self.pool.free(blocks[keep:])
            self._set_table(slot, blocks[:keep])

    def _extend_step(self) -> int:
        """One multi-token wave: plan per-slot widths, draft proposals
        for speculative slots, verify/teacher-force everything in a
        single ``extend_paged`` call, then accept/rollback.

        Slot modes — ``spec`` (no pending prompt, speculative engine):
        feed ``[t0, d_1..d_{v-1}]``, judge proposals, emit
        ``n_accepted + 1`` tokens; ``catch``: teacher-force the next
        ``v`` pending prompt tokens (sampled rows discarded until the
        prompt is consumed — the multi-token retirement of the old
        1-token-per-step catch-up); ``plain``: a slot out of room for
        proposals rides along at width 1 (vanilla semantics).
        """
        from repro.serving.spec_decode import (accept_greedy,
                                               accept_proposals,
                                               sample_from_logits)
        B, K = self.scfg.max_slots, self.K
        gamma = self.scfg.spec_gamma
        eos = self.scfg.eos_id
        with self._span("plan"):
            plan: dict[int, tuple] = {}
            for s in range(B):
                if not self.active[s]:
                    continue
                pend = self.pending[s]
                npend = 0 if pend is None else int(pend.size)
                room = self.scfg.max_len - 1 - int(self.pos[s])
                if npend:
                    plan[s] = ("catch", max(1, min(1 + npend, K, room)))
                elif self.spec is not None and min(gamma, room) >= 2:
                    plan[s] = ("spec", min(gamma, room))
                else:
                    plan[s] = ("plain", 1)
            plan = self._apply_budget(plan)
            if self.paged:
                spans = {s: v for s, (_, v) in plan.items()}
                self._ensure_blocks(spans)
                self._cow_guard(spans)
                plan = {s: p for s, p in plan.items() if self.active[s]}
            self._record_plan(plan)
        n_active = int(self.active.sum())
        if n_active == 0:
            return 0
        self.peak_active = max(self.peak_active, n_active)
        if self.paged:
            self.peak_pool_used = max(self.peak_pool_used,
                                      self.pool.num_used)

        spec_slots = [s for s, (m, _) in plan.items() if m == "spec"]
        proposals, dists = {}, {}
        if spec_slots:
            # draft only as wide as the widest planned spec span — a
            # budget-shrunk round must not burn draft steps it cannot
            # verify
            k_spec = max(v for s, (m, v) in plan.items() if m == "spec")
            with self._span("draft", slots=len(spec_slots), width=k_spec):
                proposals, dists = self.spec.propose(
                    spec_slots, self.tokens[:, 0], self.temps,
                    self.topks, k_spec, self._rng)

        fed = np.zeros((B, K), np.int32)
        valid = np.ones((B,), np.int32)
        for s, (mode, v) in plan.items():
            seq = [int(self.tokens[s, 0])]
            if mode == "catch":
                seq += [int(t) for t in self.pending[s][:v - 1]]
            elif mode == "spec":
                seq += proposals[s][:v - 1]
            fed[s, :len(seq)] = seq
            fed[s, len(seq):] = seq[-1]       # pad (write-dropped)
            valid[s] = v

        tables = jnp.asarray(self.block_tables) if self.paged else None
        # all-greedy waves ship only the (B, K) argmax ids
        need_logits = bool((self.temps[self.active] > 0).any())
        with self._span("dispatch", mode="extend", rows=n_active,
                        fed_tokens=int(sum(v for _, v in plan.values()))):
            greedy, logits, self.cache = self._extend(
                self.params, self.cache, jnp.asarray(fed),
                jnp.asarray(self.pos), jnp.asarray(valid), tables,
                need_logits=need_logits)
        if self.tracer is not None:
            # value-neutral fence separating device compute from the
            # host acceptance/sampling loop
            with self.tracer.span("sync"):
                jax.block_until_ready(greedy)
        greedy = np.asarray(greedy)                      # (B, K)
        logits = (np.asarray(logits, np.float32) if need_logits
                  else None)                             # (B, K, V)

        def sample(s, row, temp, top_k):
            if temp <= 0:
                return int(greedy[s, row])
            return sample_from_logits(logits[s, row], temp, top_k,
                                      self._rng)

        any_spec = False
        with self._span("retire"):
            for s in range(B):
                if s not in plan or not self.active[s]:
                    continue
                mode, v = plan[s]
                req = self.slot_req[s]
                temp, top_k = float(self.temps[s]), int(self.topks[s])
                if mode == "catch":
                    self._revent(req, "prefill_chunk", slot=s, n=v)
                    self.pos[s] += v
                    rest = self.pending[s][v - 1:]
                    out_of_room = (int(self.pos[s])
                                   >= self.scfg.max_len - 1)
                    if rest.size:
                        self.tokens[s, 0] = int(rest[0])
                        self.pending[s] = rest[1:]
                        if out_of_room:
                            self._finish(s, req)
                        continue
                    self._revent(req, "prompt_done", slot=s)
                    self.pending[s] = None
                    tok = sample(s, v - 1, temp, top_k)
                    self.tokens[s, 0] = tok
                    req.generated.append(tok)
                    self._rtokens(req, s, 1)
                    hit_eos = eos >= 0 and tok == eos
                    if (len(req.generated) >= req.max_new_tokens
                            or hit_eos or out_of_room):
                        self._finish(s, req)
                    continue
                if mode == "plain":
                    self.pos[s] += 1
                    tok = sample(s, 0, temp, top_k)
                    self.tokens[s, 0] = tok
                    req.generated.append(tok)
                    self._rtokens(req, s, 1)
                    hit_eos = eos >= 0 and tok == eos
                    if (len(req.generated) >= req.max_new_tokens
                            or hit_eos
                            or int(self.pos[s]) >= self.scfg.max_len - 1):
                        self._finish(s, req)
                    continue
                # speculative round
                any_spec = True
                if temp <= 0:
                    n_acc, emitted = accept_greedy(proposals[s][:v - 1],
                                                   greedy[s, :v])
                else:
                    n_acc, emitted = accept_proposals(
                        proposals[s][:v - 1], dists[s][:v - 1],
                        logits[s, :v], temp, top_k, self._rng)
                self.spec.advance(s, n_acc + 1)
                self.spec_rounds += 1
                self.spec_proposed += v - 1
                self.spec_accepted += n_acc
                # acceptance by draft depth (registry counters) + the
                # per-request round log the trace summaries aggregate
                for j in range(v - 1):
                    self.metrics.counter(f"spec.depth{j}.proposed").inc()
                for j in range(n_acc):
                    self.metrics.counter(f"spec.depth{j}.accepted").inc()
                self._revent(req, "spec_round", slot=s, proposed=v - 1,
                             accepted=n_acc)
                # budget/EOS truncation (both imply the request
                # finishes)
                emit = emitted[:req.max_new_tokens - len(req.generated)]
                if eos >= 0 and eos in emit:
                    emit = emit[:emit.index(eos) + 1]
                req.generated.extend(emit)
                self.spec_emitted += len(emit)
                self._rtokens(req, s, len(emit))
                # frontier: every emitted token except a final
                # correction/bonus was fed (and written) this wave
                self.pos[s] += min(len(emit) + 1, n_acc + 1)
                if (len(req.generated) >= req.max_new_tokens
                        or (eos >= 0 and emit and emit[-1] == eos)
                        or int(self.pos[s]) >= self.scfg.max_len - 1):
                    self._finish(s, req)
                else:
                    self.tokens[s, 0] = emit[-1]
                    self._truncate_slot(s)   # rejected-tail pages back
        if any_spec:
            self.spec_steps += 1
        with self._span("publish"):
            self._publish_frontiers()
        self.steps += 1
        return n_active

    def _publish_frontiers(self) -> None:
        """In-flight sharing: after every committed wave, publish each
        live slot's full pages below its frontier (``pos`` rounded down
        to a page boundary) into the radix tree.  The cache takes its
        own reference (``share`` + ``insert``; duplicates of the slot's
        earlier publications come straight back and are released), so a
        later request can hit a chain that is STILL decoding: readers
        pin pages strictly below the frontier, the writer only ever
        writes at/above ``pos``, and spec-decode rollback
        (``_truncate_slot``) only frees pages above ``pos`` — published
        pages are never written or yanked.  Published pages show
        refcount 2 (slot + cache) while the slot runs, so eviction and
        the admission budget both already treat them as pinned."""
        if self.prefix_cache is None:
            return
        bs = self.block_size
        for s in range(self.scfg.max_slots):
            if not self.active[s] or self.slot_req[s] is None:
                continue
            frontier = (int(self.pos[s]) // bs) * bs
            if frontier <= self.slot_published[s]:
                continue
            req = self.slot_req[s]
            key = self._key_tokens(req)
            n_blk = frontier // bs
            if len(key) < frontier or len(self.slot_blocks[s]) < n_blk:
                continue                      # reclaim-rebuilt slot mid-fold
            blocks = self.slot_blocks[s][:n_blk]
            self.pool.share(blocks)
            dups = self.prefix_cache.insert(key[:frontier], blocks,
                                            namespace=self._key_ns(req))
            self.pool.free(dups)
            self.slot_published[s] = frontier
            self.published_frontiers += 1

    def _retire_chain(self, req: Request, blocks: list[int],
                      n_valid: int) -> None:
        """Return a finished request's pages: index the chain (the
        first ``n_valid`` token positions hold valid K/V — INCLUDING a
        partial tail page, which token-granular matching can now serve)
        in the radix cache — adopting the engine's references — and
        free any duplicates of an already-indexed prefix plus pages
        past the valid span.  Non-sharable configs free everything, as
        before."""
        if not self.sharable or not blocks:
            self.pool.free(blocks)
            return
        key = self._key_tokens(req)[:n_valid]
        nb = blocks_for_tokens(n_valid, self.block_size)
        leftovers = self.prefix_cache.insert(
            key, blocks[:nb], namespace=self._key_ns(req))
        self.pool.free(list(leftovers) + list(blocks[nb:]))

    def _finish(self, slot: int, req: Request) -> None:
        self._revent(req, "finish", slot=slot,
                     n_generated=len(req.generated))
        self._slot_end(slot)
        req.done = True
        self.completed.append(req)
        self.active[slot] = False
        self.slot_req[slot] = None
        self.pending[slot] = None
        self.slot_published[slot] = 0
        if self.paged:
            # KV is valid for [0, pos): everything written by prefill,
            # catch-up and decode waves (the final sampled token was
            # never fed back, so pos stops short of it)
            self._retire_chain(req, self.slot_blocks[slot],
                               int(self.pos[slot]))
            self._set_table(slot, [])

    # ------------------------------------------------------------------
    # prefix-store persistence (warm TTFT across engine restarts)
    # ------------------------------------------------------------------
    def _persist_meta(self) -> dict:
        """Prefix-store header: page geometry, a config digest and a
        params fingerprint.  ``load_store`` refuses a store whose
        header differs — persisted KV bytes are only valid for the
        exact (config, params, page layout) that produced them."""
        from repro.serving.prefix_cache import PERSIST_VERSION
        cfg_digest = hashlib.sha1(repr(self.cfg).encode()).hexdigest()
        fp = hashlib.sha1()
        for lv in jax.tree.leaves(self.params):
            # shape/dtype of every leaf plus a value sample FROM every
            # leaf: a checkpoint that differs anywhere (partial
            # fine-tune, different seed) must trip the fingerprint —
            # persisted KV is a function of the weights
            fp.update(str((tuple(lv.shape), str(lv.dtype))).encode())
            fp.update(np.asarray(jnp.ravel(lv)[:64]).tobytes())
        sig = []
        for lv, ax in zip(jax.tree.leaves(self.cache),
                          jax.tree.leaves(self.axes)):
            if ax < 0:              # pool leaf: (stack, nB, bs, kv...)
                shape = lv.shape[:1] + lv.shape[2:]
                sig.append([list(shape), str(lv.dtype)])
        return {"version": PERSIST_VERSION, "config": cfg_digest,
                "params": fp.hexdigest(), "block_size": self.block_size,
                # pins the pool quant layout explicitly (the leaf sigs
                # already differ — int8 dtypes + extra scale leaves —
                # but the key makes an f32<->int8 mismatch legible in
                # the rejection reason): spilled stores carry int8 page
                # bytes + scales and are only valid for the same layout
                "quant_kv": self.scfg.quant_kv if self.quant else None,
                "leaves": sig}

    def _chain_pages_host(self, blocks) -> list[np.ndarray]:
        """Gather one chain's page bytes to the host: one
        ``(stack, n_chain_blocks, block_size, kv...)`` array per pool
        leaf, in cache-leaf order."""
        ids = np.asarray(blocks, np.int32)
        return [np.asarray(lv[:, ids])
                for lv, ax in zip(jax.tree.leaves(self.cache),
                                  jax.tree.leaves(self.axes)) if ax < 0]

    def _spill_chain(self, ns: int, key, n_leaf: int, blocks) -> None:
        """``RadixPrefixCache.on_evict`` hook (persist mode only):
        capture an evicted chain's pages BEFORE the pool reclaims them
        so pressure-evicted chains still make it into the close()-time
        store.  Spill is capped at one pool's worth of pages — beyond
        that a restart could not rehydrate them anyway."""
        held = sum(blocks_for_tokens(len(k), self.block_size)
                   for _, k, _ in self._spilled)
        if held + len(blocks) > self.pool.num_blocks:
            return
        self._spilled.append((ns, np.asarray(key, np.int64),
                              self._chain_pages_host(blocks)))

    def close(self) -> dict:
        """Flush the radix cache's hot refcount-free chains (plus any
        pressure-spilled ones) to ``ServeConfig.prefix_persist_path``
        so the NEXT engine with this path starts with a warm cache.
        Safe to call on any engine (no-op without a path / on
        non-sharable configs); returns a save summary."""
        path = self.scfg.prefix_persist_path
        if not path or not self.sharable:
            return {"persist_saved_chains": 0, "persist_saved_blocks": 0}
        # resident chains carry their block ids; spilled chains already
        # carry host page bytes.  Dedup on (namespace, key) FIRST —
        # gathering device pages for a chain the dedup would discard is
        # pure wasted transfer at shutdown.
        cand = [(ns, key, ("blocks", blocks))
                for ns, key, blocks in
                dump_chains(self.prefix_cache,
                            max_blocks=self.pool.num_blocks)]
        cand += [(ns, key, ("pages", pages))
                 for ns, key, pages in self._spilled]
        # BIDIRECTIONAL prefix dedup (exact duplicates keep the first,
        # hot-first, occurrence): a chain that is a prefix of any other
        # stored chain is fully covered by it — same tokens produce the
        # same KV bytes — and a store holding both a partial-tail chain
        # AND its extension would drive insert's replacement path at
        # rehydrate (page churn for nothing).
        chains = []
        for i, (ns, key, payload) in enumerate(cand):
            covered = False
            for j, (n2, k2, _) in enumerate(cand):
                if j == i or n2 != ns or len(key) > len(k2):
                    continue
                if len(key) == len(k2) and j > i:
                    continue                   # equal twins: first wins
                if np.array_equal(key, k2[:len(key)]):
                    covered = True
                    break
            if not covered:
                kind, data = payload
                pages = (self._chain_pages_host(data) if kind == "blocks"
                         else data)
                chains.append((ns, key, pages))
        info = save_store(path, self._persist_meta(), chains)
        return {"persist_saved_chains": info["chains"],
                "persist_saved_blocks": info["blocks"]}

    def _load_prefix_store(self, path: str) -> None:
        """Rehydrate a persisted prefix store at construction: allocate
        pool pages, scatter the stored page bytes into the device cache
        and index the chains in the radix tree.  A mismatched or
        corrupt store is REJECTED (reason in ``persist_rejected`` /
        ``stats()``) and the engine simply starts cold."""
        if not os.path.exists(path):
            return
        try:
            chains = load_store(path, self._persist_meta())
        except PrefixStoreError as e:
            self.persist_rejected = str(e)
            return
        leaves, treedef = jax.tree_util.tree_flatten(self.cache)
        axes = jax.tree.leaves(self.axes)
        pool_idx = [i for i, a in enumerate(axes) if a < 0]
        # physical page id -> per-pool-leaf page bytes.  LAST write wins:
        # a page freed mid-load (insert's dup return, or its internal
        # partial-tail replacement) can be re-alloc'd by a later chain,
        # and that later chain owns the page — dict overwrite keeps
        # exactly its payload, never a stale one, and the final
        # refcount filter drops pages that ended up back in the pool.
        pending: dict[int, list[np.ndarray]] = {}
        for ns, key, pages in chains:        # hot-first store order
            nb = blocks_for_tokens(len(key), self.block_size)
            if not self.pool.can_alloc(nb):
                continue
            ids = self.pool.alloc(nb)
            dups = self.prefix_cache.insert(key, ids, namespace=ns)
            nd = len(dups)       # dups are always a PREFIX of ids
            for k in range(nd, nb):
                pending[ids[k]] = [pages[j][:, k]
                                   for j in range(len(pool_idx))]
            self.pool.free(dups)
            self.persist_loaded_chains += 1
            self.persist_loaded_blocks += nb - nd
        pending = {bid: v for bid, v in pending.items()
                   if self.pool.refcount(bid) > 0}
        if pending:
            # ONE scatter per pool leaf — per-chain .at[].set would copy
            # the full (possibly multi-GB) pool tensor once per chain
            order = list(pending)
            arr = np.asarray(order, np.int32)
            for j, li in enumerate(pool_idx):
                chunk = np.stack([pending[b][j] for b in order], axis=1)
                leaves[li] = leaves[li].at[:, arr].set(
                    jnp.asarray(chunk, leaves[li].dtype))
        self.cache = jax.tree_util.tree_unflatten(treedef, leaves)

    # ------------------------------------------------------------------
    # telemetry (serving/telemetry.py)
    # ------------------------------------------------------------------
    def _register_metrics(self) -> dict:
        """Register every serving counter/gauge into the metrics
        registry and return the ``stats()`` compatibility map
        ``{legacy_key: metric_name}`` — built per config axis exactly
        like the historical ad-hoc dict, so the view's key set and
        values are unchanged (snapshot-tested in
        ``tests/test_telemetry.py``).  Counters that tests reset by
        assignment stay plain attributes; the registry samples them via
        callback gauges."""
        m, legacy = self.metrics, {}

        def view(key: str, name: str, fn) -> None:
            m.gauge(name, fn)
            legacy[key] = name

        view("steps", "engine.steps", lambda: self.steps)
        view("peak_active", "engine.peak_active", lambda: self.peak_active)
        view("peak_pool_used", "engine.peak_pool_used",
             lambda: self.peak_pool_used)
        view("exhaust_preempts", "engine.exhaust_preempts",
             lambda: self.exhaust_preempts)
        view("reclaims", "engine.reclaims", lambda: self.reclaims)
        view("cow_forks", "engine.cow_forks", lambda: self.cow_forks)
        view("mixed_waves", "engine.mixed_waves", lambda: self.mixed_waves)
        view("wave_admitted", "engine.wave_admitted",
             lambda: self.wave_admitted)
        view("cancels", "engine.cancels", lambda: self.cancels)
        if self.paged:
            self.pool.attach_metrics(m)
            legacy.update(pool_blocks="kv_pool.blocks",
                          pool_free="kv_pool.free",
                          pool_shared="kv_pool.shared")
        if self.quant or self.scfg.quant_draft:
            from repro.serving.kv_pool import page_bytes
            view("quant_kv", "quant.kv", lambda: self.scfg.quant_kv or "")
            view("quant_draft", "quant.draft",
                 lambda: bool(self.scfg.quant_draft
                              and self.spec is not None))
            # deterministic capacity facts for the baseline gate:
            # bytes of one page under this layout vs f32
            view("quant_page_bytes", "quant.page_bytes",
                 lambda: page_bytes(self.cfg, self.block_size,
                                    self.scfg.quant_kv
                                    if self.quant else None))
            view("quant_f32_page_bytes", "quant.f32_page_bytes",
                 lambda: page_bytes(self.cfg, self.block_size, None))
        if self.scfg.spec_decode:
            view("spec_active", "spec.active",
                 lambda: self.spec is not None)
            view("spec_steps", "spec.steps", lambda: self.spec_steps)
            view("spec_rounds", "spec.rounds", lambda: self.spec_rounds)
            view("spec_proposed", "spec.proposed",
                 lambda: self.spec_proposed)
            view("spec_accepted", "spec.accepted",
                 lambda: self.spec_accepted)
            view("spec_emitted", "spec.emitted", lambda: self.spec_emitted)
            view("spec_acceptance", "spec.acceptance",
                 lambda: self.spec_accepted / max(self.spec_proposed, 1))
            # mean big-model tokens emitted per verify round per slot:
            # 1.0 = vanilla; > 1 = speculation paying off
            view("spec_tokens_per_round", "spec.tokens_per_round",
                 lambda: self.spec_emitted / max(self.spec_rounds, 1))
            # acceptance by DRAFT DEPTH: position j of a proposal
            # within its round (acceptance decays with depth — the
            # signal that picks gamma); bumped in _extend_step
            for j in range(max(self.scfg.spec_gamma - 1, 0)):
                m.counter(f"spec.depth{j}.proposed")
                m.counter(f"spec.depth{j}.accepted")
        if self.prefix_cache is not None:
            for k in self.prefix_cache.attach_metrics(m):
                legacy[f"prefix_{k}"] = f"prefix_cache.{k}"
            view("published_frontiers", "engine.published_frontiers",
                 lambda: self.published_frontiers)
            if self.scfg.prefix_persist_path:
                view("persist_loaded_chains", "persist.loaded_chains",
                     lambda: self.persist_loaded_chains)
                view("persist_loaded_blocks", "persist.loaded_blocks",
                     lambda: self.persist_loaded_blocks)
                view("persist_spilled_chains", "persist.spilled_chains",
                     lambda: len(self._spilled))
                view("persist_rejected", "persist.rejected",
                     lambda: self.persist_rejected)
        return legacy

    def _span(self, name: str, **args):
        """Engine-phase span when tracing; a free no-op context
        otherwise (the untraced step path stays branch-for-branch what
        it was)."""
        if self.tracer is None:
            return nullcontext()
        return self.tracer.span(name, **args)

    def _revent(self, req: Request, name: str, slot: Optional[int] = None,
                **args) -> None:
        """Per-request lifecycle event (no-op untraced): mirrored onto
        the slot's track when resident, the frontend track otherwise."""
        if self.tracer is not None:
            self.tracer.req_event(
                req.uid, name,
                tid=None if slot is None else SLOT_TID0 + slot, **args)

    def _rtokens(self, req: Request, slot: int, n: int) -> None:
        """Token-retirement stamps, called AFTER appending ``n`` tokens
        to ``req.generated``: ``first_token`` closes the TTFT
        decomposition, ``tokens`` feeds the per-request ITL series."""
        if self.tracer is None or n <= 0:
            return
        if len(req.generated) == n:
            self._revent(req, "first_token", slot=slot)
        self._revent(req, "tokens", slot=slot, n=n)

    def _slot_begin(self, req: Request, slot: int) -> None:
        """Open the slot-residency span on the slot's trace track."""
        if self.tracer is not None:
            tid = SLOT_TID0 + slot
            self.tracer.name_track(tid, f"slot{slot}")
            self.tracer.begin(f"u{req.uid}", tid, uid=req.uid)

    def _slot_end(self, slot: int) -> None:
        if self.tracer is not None:
            self.tracer.end(SLOT_TID0 + slot)

    def dump_chrome_trace(self, path: str) -> dict:
        """Write the tracer's Perfetto / chrome://tracing JSON dump to
        ``path`` (engine phases on one track, one track per slot,
        per-request ``request_summary`` instants carrying the TTFT
        decomposition).  Requires ``ServeConfig.trace=True``."""
        if self.tracer is None:
            raise ValueError(
                "tracing is off — construct the engine with "
                "ServeConfig(trace=True) to record a trace")
        return self.tracer.dump_chrome_trace(path)

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Pool / prefix-cache observability — a compatibility VIEW
        over the metrics registry (``serving/telemetry.py``): same keys
        and values as the historical ad-hoc dict, now read through the
        registered gauges so every subsystem reports through one path.
        Every call re-checks the pool accounting invariant (free +
        refcounted == total)."""
        if self.paged:
            self.pool.assert_consistent()
        return {key: self.metrics.get(name)
                for key, name in self._legacy_stats.items()}

    # ------------------------------------------------------------------
    def cancel(self, uid: int) -> bool:
        """Abort a request mid-flight — queued, preempted-and-detached,
        mid-catch-up, mid-speculation or plain decoding.  Returns True
        when the request was found (it is marked ``cancelled`` + ``done``
        and moved to ``self.cancelled``, never ``completed``).

        KV semantics mirror ``_finish``: a live slot's pages below
        ``pos`` hold a fully valid chain and retire into the radix
        cache (published frontier pages keep their cache reference, so
        in-flight readers of the cancelled chain are untouched);
        non-sharable configs free everything.  A stale draft row needs
        no cleanup (re-admission rewrites it), and no wave ever sees
        the slot again — cancellation between waves can never roll back
        tokens already delivered.
        """
        for i, req in enumerate(self.queue):
            if req.uid != uid:
                continue
            self.queue.pop(i)
            if self.sharable:
                self._release_ctx(req)       # drop a pinned hit chain
            st = req.saved_state
            if st is not None:
                req.saved_state = None
                if self.paged:
                    self.pool.free(st.get("blocks", ()))
            self._mark_cancelled(req)
            return True
        for s in range(self.scfg.max_slots):
            req = self.slot_req[s]
            if not self.active[s] or req is None or req.uid != uid:
                continue
            self._slot_end(s)
            self.active[s] = False
            self.slot_req[s] = None
            self.pending[s] = None
            self.slot_published[s] = 0
            if self.paged:
                self._retire_chain(req, self.slot_blocks[s],
                                   int(self.pos[s]))
                self._set_table(s, [])
            self._mark_cancelled(req)
            return True
        return False

    def _mark_cancelled(self, req: Request) -> None:
        self._revent(req, "cancel", n_generated=len(req.generated))
        req.done = True
        req.cancelled = True
        self.cancelled.append(req)
        self.cancels += 1

    # ------------------------------------------------------------------
    def preempt(self, slot: int) -> Optional[Request]:
        """Evict a running request (scheduler-driven preemption), taking
        its dense cache leaves and decode position with it; its KV pages
        stay in the pool, DETACHED onto the request — re-submission
        restores the block table and resumes decode exactly where it
        stopped, with NO re-prefill and no page copies."""
        req = self.slot_req[slot]
        if req is None:
            return None
        self._revent(req, "preempt", slot=slot)
        self._slot_end(slot)
        req.saved_state = {
            "cache": extract_slot(self.cache, slot, self.axes),
            "pos": int(self.pos[slot]),
            "last_tok": int(self.tokens[slot, 0]),
            "pending": self.pending[slot],
        }
        if self.spec is not None:
            req.saved_state["draft"] = self.spec.extract(slot)
        if self.paged:
            req.saved_state["blocks"] = self.slot_blocks[slot]
            req.saved_state["published"] = self.slot_published[slot]
            self._set_table(slot, [])
        self.active[slot] = False
        self.slot_req[slot] = None
        self.pending[slot] = None
        self.slot_published[slot] = 0
        return req

    # ------------------------------------------------------------------
    def _drop_saved(self, req: Request) -> None:
        """Forced reclaim under pool exhaustion: release the detached
        pages and rebuild the request as a fresh catch-up prompt
        (original prompt + tokens generated so far).  Re-prefill IS
        required for this one request — the escape hatch that keeps
        ``run_until_drained`` live when detached holders own every page.
        The exact context is replayed, but prefill and decode logits
        only agree to bf16 tolerance, so a greedy tie can flip: the
        contract here is liveness + correct token budget, not the
        bit-exactness the detach/resume path guarantees."""
        st = req.saved_state
        req.saved_state = None
        self.pool.free(st.get("blocks", ()))
        # fold only the not-yet-folded suffix of generated into the
        # replay prompt: a request reclaimed twice must not see its
        # first batch of generated tokens duplicated in the context
        folded = getattr(req, "_folded_generated", 0)
        fresh = req.generated[folded:]
        if fresh:
            req.prompt = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(fresh, np.int32)])
            req._folded_generated = len(req.generated)

    def _reclaim(self) -> None:
        holders = [r for r in self.queue
                   if r.saved_state is not None
                   and r.saved_state.get("blocks")]
        if not holders:
            raise RuntimeError(
                "serving pool wedged: no active slots, queue non-empty, "
                "and no detached pages to reclaim (pool misconfigured?)")
        victim = max(holders, key=self._rank)   # worst-ranked holder
        self._drop_saved(victim)
        self.reclaims += 1

    def drain_step(self) -> int:
        """One ``step()`` with the pool accounting invariant re-checked
        after it — the unit of progress ``run_until_drained`` iterates
        (pool-wedge recovery now lives in ``step()`` itself, so bare
        ``step()`` loops — the asyncio frontend — are equally live)."""
        stepped = self.step()
        if self.paged:
            self.pool.assert_consistent()   # accounting drift backstop
        return stepped

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        while (self.queue or self.active.any()) and self.steps < max_steps:
            self.drain_step()
        return self.completed
