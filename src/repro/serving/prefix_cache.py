"""Radix-tree prefix cache over the paged KV pool.

The EdgeAI-Hub premise — shared resources across users instead of
per-device duplication — applied inside the serving engine: every
household request carries the same system/persona prefix, and with a
paged KV cache those prefix pages can be SHARED by reference instead of
re-prefilled and re-stored per request.

This module is the host-side index that makes the sharing findable: a
radix tree mapping token-id prefixes to page chains at TOKEN
granularity.  Full ``block_size``-token pages are shared zero-copy
(suffix writes land past them); a match that ends mid-page — a partial
final block, either because the query diverges inside a cached page or
because the cached chain itself ends mid-page — is still returned, and
the ENGINE copy-on-write-forks that one page (``KVBlockPool.fork`` +
device page copy) so its suffix writes never touch the shared copy.
Tree structure stays block-aligned: edges split only on page
boundaries, and an edge whose key length is not a page multiple is
always a leaf (a finished chain's partial tail page, adopted verbatim).

Ownership protocol (mirrors vLLM/SGLang)
----------------------------------------
* The cache holds exactly ONE pool reference per indexed page.
* ``match(key)`` walks the tree, bumps LRU stamps, and increfs every
  matched page **on behalf of the reader** — the engine then owns those
  pages like any allocation (frees on finish, detaches on preempt).
* ``insert(key, blocks)`` adopts the caller's references for pages that
  extend the tree and returns the caller's now-duplicate ids (prefix
  already indexed, possibly under different physical pages) for the
  caller to free.  Inserting never allocates.  A chain that diverges
  from a resident chain in the MIDDLE of a page cannot be keyed apart
  in a radix over pages — the resident chain wins and the incoming
  tail is returned unadopted.  A chain that extends a resident partial
  tail replaces that tail page (the cache releases its own reference on
  the superseded page) and adopts the longer chain.
* ``evict(n)`` releases LRU leaf chains whose pages have pool refcount
  1 (the cache is the sole owner — nothing active reads them) until
  ``n`` pages went back to the free list.  Chains pinned by readers are
  skipped, so eviction can never yank KV out from under a running
  request.  ``on_evict`` (if set) observes each victim chain BEFORE its
  pages are freed — the engine's persistence spill hook.
* In-flight sharing is the same protocol driven by the engine: a live
  slot increfs its full pages below the committed frontier and
  ``insert``s them; duplicates (its own earlier publication) come back
  and are freed, so the cache still ends up holding exactly one
  reference per page while the writer keeps decoding ABOVE the
  published frontier.

Keys are ``np.int64`` sequences: plain token ids for text-only
families, with a per-request ``namespace`` (a digest of the non-token
inputs — VLM image embeds, enc-dec audio) separating subtrees whose KV
depends on more than the token ids.

Persistence
-----------
``dump_chains`` enumerates the refcount-free (cache-only) root-to-leaf
chains hot-first; ``save_store``/``load_store`` serialize them — token
keys plus the per-layer page bytes the engine gathers/scatters — to a
host-side ``.npz`` with a metadata header (config digest, params
fingerprint, page geometry).  ``load_store`` REFUSES a corrupt or
mismatched store with ``PrefixStoreError`` so a restarted hub falls
back to a cold start instead of serving another model's KV.
"""
from __future__ import annotations

import itertools
import json
from typing import Callable, Optional

import numpy as np

from repro.serving.kv_pool import KVBlockPool, blocks_for_tokens

PERSIST_VERSION = 1


class PrefixStoreError(ValueError):
    """A persisted prefix store is corrupt or belongs to a different
    engine configuration — callers must fall back to a cold start."""


class _Node:
    """One radix edge: ``key`` (any token length; a non-page-multiple
    length makes this a childless partial-tail leaf) and the page chain
    holding its KV; children keyed by their first token."""

    __slots__ = ("key", "blocks", "children", "parent", "stamp")

    def __init__(self, key: np.ndarray, blocks: list[int],
                 parent: Optional["_Node"]):
        self.key = key
        self.blocks = blocks
        self.children: dict[int, _Node] = {}
        self.parent = parent
        self.stamp = 0


def _common_tokens(edge_key: np.ndarray, key: np.ndarray, pos: int) -> int:
    """Token-granular common prefix of ``edge_key`` and ``key[pos:]``."""
    lim = min(len(edge_key), len(key) - pos)
    if lim <= 0:
        return 0
    neq = np.nonzero(edge_key[:lim] != key[pos:pos + lim])[0]
    return int(neq[0]) if neq.size else lim


class RadixPrefixCache:
    """Token-granularity radix index of (possibly in-flight) chains in
    ``pool``.

    ``on_evict(namespace, full_key, n_leaf_tokens, full_blocks)`` — if
    set — observes every evicted leaf chain before its pages return to
    the pool: ``full_key``/``full_blocks`` cover the whole root-to-leaf
    path (only the leaf's own pages are actually freed; ancestors stay
    indexed), ``n_leaf_tokens`` is the evicted edge's token count.
    """

    def __init__(self, pool: KVBlockPool, block_size: Optional[int] = None,
                 on_evict: Optional[Callable] = None,
                 min_match_tokens: int = 1):
        self.pool = pool
        self.block_size = int(block_size or pool.block_size)
        self.on_evict = on_evict
        # admission floor: a match shorter than this many tokens is
        # reported as a MISS (a 1-token accidental hit makes the caller
        # CoW-fork a page for near-zero reuse).  1 accepts any hit.
        self.min_match_tokens = max(1, int(min_match_tokens))
        self.short_matches = 0        # matches rejected by the floor
        # roots per namespace: extras-digest -> top-level node
        self._roots: dict[int, _Node] = {}
        self._clock = itertools.count(1)
        self.hits = 0
        self.misses = 0
        self.hit_blocks = 0
        self.hit_tokens = 0
        # counterfactual: what the PR-3 block-granular matcher would
        # have returned for the same queries — the benchmark's proof
        # that token-granular matching strictly increases reuse
        self.hit_tokens_block = 0
        self.evicted_blocks = 0
        self.inserted_blocks = 0
        self.replaced_blocks = 0      # partial tails superseded by longer chains
        # hit-length histogram, live only after attach_metrics (telemetry)
        self._m_hit_hist = None

    # ------------------------------------------------------------------
    def _root(self, namespace: int) -> _Node:
        if namespace not in self._roots:
            self._roots[namespace] = _Node(np.zeros((0,), np.int64), [], None)
        return self._roots[namespace]

    def _match_walk(self, namespace: int, key: np.ndarray):
        """Longest token-granular match: returns (nodes touched, blocks,
        matched token count).  Pure walk — no refcounts, no stamps."""
        bs = self.block_size
        node = self._roots.get(namespace)
        if node is None:
            return [], [], 0
        nodes, blocks, matched = [node], [], 0
        pos = 0
        while pos < len(key):
            child = node.children.get(int(key[pos]))
            if child is None:
                break
            c = _common_tokens(child.key, key, pos)
            if c == 0:
                break
            nodes.append(child)
            blocks.extend(child.blocks[:blocks_for_tokens(c, bs)])
            matched += c
            pos += c
            if c < len(child.key):
                break                      # stopped mid-edge
            node = child
        return nodes, blocks, matched

    # ------------------------------------------------------------------
    def match(self, key, namespace: int = 0,
              max_tokens: Optional[int] = None):
        """Longest shared prefix of ``key`` already in the cache, at
        TOKEN granularity.

        Returns ``(blocks, n_tokens)``: ``blocks`` covers
        ``ceil(n_tokens / block_size)`` pages; when ``n_tokens`` is not
        a page multiple the LAST page is only partially matched — the
        caller must CoW-fork it before writing its suffix (positions
        ``>= n_tokens`` of that page hold another chain's KV and are
        only masked, not absent).  ``n_tokens`` is capped at
        ``max_tokens`` (callers cap at ``len(prompt) - 1`` so at least
        one suffix token remains to produce admission logits).  Every
        returned page is incref'd FOR THE CALLER, and the touched nodes
        are LRU-stamped.
        """
        key = np.asarray(key, np.int64)
        bs = self.block_size
        nodes, blocks, matched = self._match_walk(namespace, key)
        raw = matched
        if max_tokens is not None and matched > max_tokens:
            matched = max_tokens
        blocks = blocks[:blocks_for_tokens(matched, bs)]
        if 0 < matched < self.min_match_tokens:
            # below the admission floor: no refcounts taken, no LRU
            # stamp — the caller proceeds exactly as on a cold miss
            self.short_matches += 1
            matched = 0
        if matched == 0:
            self.misses += 1
            return [], 0
        stamp = next(self._clock)
        for nd in nodes:
            nd.stamp = stamp
        self.pool.share(blocks)
        self.hits += 1
        self.hit_blocks += len(blocks)
        self.hit_tokens += matched
        if self._m_hit_hist is not None:
            self._m_hit_hist.observe(matched)
        bg_cap = (raw if max_tokens is None
                  else (max_tokens // bs) * bs)
        self.hit_tokens_block += min((raw // bs) * bs, bg_cap)
        return list(blocks), matched

    def unrecord_hit(self, n_blocks: int, n_tokens: int = 0,
                     n_tokens_block: int = 0) -> None:
        """Roll back one recorded hit whose chain the reader released
        WITHOUT using it (e.g. admission skipped the request this
        round and will re-match later) — keeps ``hits``/``hit_*``
        meaning "admissions actually served from the cache" instead of
        counting every retry of the same queued request."""
        self.hits -= 1
        self.hit_blocks -= n_blocks
        self.hit_tokens -= n_tokens
        self.hit_tokens_block -= n_tokens_block

    # ------------------------------------------------------------------
    def _split(self, node: _Node, at: int) -> None:
        """Split ``node``'s edge after ``at`` tokens (page multiple):
        node keeps the head, a new child gets the tail + old children."""
        bs = self.block_size
        assert at % bs == 0, "edges split on page boundaries only"
        tail = _Node(node.key[at:], node.blocks[at // bs:], node)
        tail.children = node.children
        for c in tail.children.values():
            c.parent = tail
        tail.stamp = node.stamp
        node.key = node.key[:at]
        node.blocks = node.blocks[:at // bs]
        node.children = {int(tail.key[0]): tail}

    def insert(self, key, blocks: list[int], namespace: int = 0) -> list[int]:
        """Index ``blocks`` (pages covering ``key``, the last one
        possibly partial) under the tree, adopting the caller's pool
        references for pages that extend it.  Returns the caller's ids
        made redundant by an existing indexed prefix — the caller must
        free those.  ``len(blocks)`` must equal
        ``blocks_for_tokens(len(key))``.

        Adoption rules (the oracle the property suite checks): with
        ``m`` = the longest token prefix of ``key`` already indexed,
        the tail past ``m`` is adopted iff ``m`` lands on a page
        boundary (new child / edge split) or exactly at the end of a
        resident partial-tail leaf (the leaf's partial page is released
        and the longer chain replaces it).  A divergence in the middle
        of a resident page keeps the resident chain and refuses the
        incoming tail — two chains cannot share a physical page they
        disagree on.
        """
        key = np.asarray(key, np.int64)
        bs = self.block_size
        if len(blocks) != blocks_for_tokens(len(key), bs):
            raise ValueError(
                f"insert: key of {len(key)} tokens vs {len(blocks)} "
                f"blocks of {bs}")
        if not blocks:
            return []
        node = self._root(namespace)
        pos = 0                                  # always page-aligned here
        stamp = next(self._clock)
        node.stamp = stamp
        while pos < len(key):
            child = node.children.get(int(key[pos]))
            if child is None:
                new = _Node(key[pos:], list(blocks[pos // bs:]), node)
                new.stamp = stamp
                node.children[int(key[pos])] = new
                self.inserted_blocks += len(new.blocks)
                return list(blocks[:pos // bs])     # duplicates of prefix
            c = _common_tokens(child.key, key, pos)
            child.stamp = stamp
            rem = len(key) - pos
            if c == len(child.key):
                if len(child.key) % bs == 0:
                    pos += c
                    node = child
                    continue                      # full aligned edge: descend
                # resident partial-tail leaf fully matched
                if c == rem:
                    return list(blocks)           # incoming ends with it
                # incoming EXTENDS the partial tail: replace the
                # superseded partial page with the longer chain's pages
                fb = len(child.key) // bs
                old_tail = child.blocks[fb:]
                child.key = key[pos:]
                child.blocks = (child.blocks[:fb]
                                + list(blocks[pos // bs + fb:]))
                self.pool.free(old_tail)          # cache's own reference
                self.replaced_blocks += len(old_tail)
                self.inserted_blocks += len(blocks) - (pos // bs + fb)
                return list(blocks[:pos // bs + fb])
            # c < len(child.key): incoming ran out or diverged mid-edge
            if c == rem:
                return list(blocks)               # prefix of resident: dup
            cb = (c // bs) * bs
            if c % bs != 0 or cb == 0:
                # divergence inside a page: the resident chain keeps the
                # page; the incoming tail cannot be keyed apart
                return list(blocks)
            self._split(child, cb)
            new = _Node(key[pos + cb:], list(blocks[(pos + cb) // bs:]),
                        child)
            new.stamp = stamp
            child.children[int(key[pos + cb])] = new
            self.inserted_blocks += len(new.blocks)
            return list(blocks[:(pos + cb) // bs])
        return list(blocks)                          # fully duplicate

    # ------------------------------------------------------------------
    def _evictable(self, node: _Node) -> bool:
        """A subtree is evictable iff every page in it has pool
        refcount 1 (the cache's own reference) — no active reader."""
        return all(self.pool.refcount(b) == 1 for b in node.blocks) and \
            all(self._evictable(c) for c in node.children.values())

    def evictable_blocks(self) -> int:
        """Pages the cache could return to the pool RIGHT NOW (maximal
        evictable subtrees) — admission counts these as available."""
        def count(node: _Node) -> int:
            if self._evictable(node):
                return self._size(node)
            return sum(count(c) for c in node.children.values())
        return sum(count(r) for r in self._roots.values())

    @staticmethod
    def _size(node: _Node) -> int:
        return len(node.blocks) + sum(RadixPrefixCache._size(c)
                                      for c in node.children.values())

    def _leaves(self) -> list[tuple[int, "_Node"]]:
        out = []

        def walk(ns, node):
            if not node.children and node.parent is not None:
                out.append((ns, node))
            for c in node.children.values():
                walk(ns, c)
        for ns, r in self._roots.items():
            walk(ns, r)
        return out

    @staticmethod
    def _full_path(node: _Node) -> tuple[np.ndarray, list[int]]:
        """(full key, full block chain) for the root-to-``node`` path."""
        keys, blocks, nd = [], [], node
        while nd is not None and nd.parent is not None:
            keys.append(nd.key)
            blocks = list(nd.blocks) + blocks
            nd = nd.parent
        key = (np.concatenate(keys[::-1]) if keys
               else np.zeros((0,), np.int64))
        return key, blocks

    def evict(self, n_blocks: int) -> int:
        """Free LRU leaf chains (cache-only pages) until ``n_blocks``
        pages returned to the pool or nothing more is evictable.
        Returns the number of pages actually freed."""
        freed = 0
        while freed < n_blocks:
            leaves = [(ns, lf) for ns, lf in self._leaves()
                      if all(self.pool.refcount(b) == 1
                             for b in lf.blocks)]
            if not leaves:
                break
            ns, victim = min(leaves, key=lambda t: t[1].stamp)
            if self.on_evict is not None:
                full_key, full_blocks = self._full_path(victim)
                self.on_evict(ns, full_key, len(victim.key), full_blocks)
            self.pool.free(victim.blocks)
            freed += len(victim.blocks)
            self.evicted_blocks += len(victim.blocks)
            parent = victim.parent
            del parent.children[int(victim.key[0])]
        return freed

    # ------------------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        """Pages currently indexed (cache holds one ref each)."""
        return sum(self._size(r) for r in self._roots.values())

    def attach_metrics(self, registry) -> list:
        """Register one ``prefix_cache.<key>`` callback gauge per
        ``stats()`` field into a ``serving.telemetry.MetricsRegistry``
        plus a ``prefix_cache.hit_tokens_hist`` histogram (tokens per
        served hit, observed by ``match``; ``unrecord_hit`` cannot roll
        a histogram sample back, so the histogram counts *recorded*
        hits, the gauges count *served* ones).  Returns the stats keys
        in dict order so callers can build compatibility views."""
        keys = list(self.stats().keys())
        for k in keys:
            registry.gauge(f"prefix_cache.{k}",
                           (lambda k=k: self.stats()[k]))
        self._m_hit_hist = registry.histogram(
            "prefix_cache.hit_tokens_hist",
            (16, 32, 64, 128, 256, 512, 1024))
        return keys

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "hit_blocks": self.hit_blocks,
            "hit_tokens": self.hit_tokens,
            "hit_tokens_block": self.hit_tokens_block,
            "cached_blocks": self.num_blocks,
            "evicted_blocks": self.evicted_blocks,
            "inserted_blocks": self.inserted_blocks,
            "replaced_blocks": self.replaced_blocks,
            "short_matches": self.short_matches,
        }

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"RadixPrefixCache(blocks={self.num_blocks}, "
                f"hits={self.hits}, misses={self.misses})")


# ---------------------------------------------------------------------------
# persistence: hot chains across engine restarts
# ---------------------------------------------------------------------------

def dump_chains(cache: RadixPrefixCache, max_blocks: Optional[int] = None):
    """Enumerate refcount-free chains for persistence, hot-first.

    Returns ``[(namespace, full_key, full_blocks), ...]`` — one entry
    per leaf, covering the whole root-to-leaf path, truncated at the
    first node whose pages a reader still pins.  Pins are root-anchored
    (match/publish/preempt all hold root-to-k prefixes), so a chain
    with ANY pinned page is in practice skipped whole — "refcount-free
    chains" only; call this after drain (``engine.close()`` at
    shutdown) to persist everything.  ``max_blocks`` caps the total
    page budget (hot chains win; a chain that does not fit whole is
    skipped, shared-prefix pages are counted once).

    Known flat-store limitation (ROADMAP follow-up: tree-structured
    store): the BUDGET dedups shared-prefix pages but the serialized
    chains each carry their full root-to-leaf page bytes, so sibling
    chains duplicate their common prefix on disk, and rehydration
    transiently allocates a chain's full length before ``insert``
    hands the duplicate prefix pages back — a pool sized exactly to
    the deduped footprint can skip late chains that would have fit."""
    out, seen_pages, seen_keys = [], set(), set()
    budget = max_blocks if max_blocks is not None else float("inf")
    leaves = sorted(cache._leaves(), key=lambda t: -t[1].stamp)
    for ns, leaf in leaves:
        # path root->leaf, truncated at the first pinned node
        path, nd = [], leaf
        while nd is not None and nd.parent is not None:
            path.append(nd)
            nd = nd.parent
        path = path[::-1]
        keys, blocks = [], []
        for nd in path:
            if any(cache.pool.refcount(b) != 1 for b in nd.blocks):
                break
            keys.append(nd.key)
            blocks.extend(nd.blocks)
        if not blocks:
            continue
        full_key = np.concatenate(keys)
        ident = (ns, full_key.tobytes())
        if ident in seen_keys:
            continue     # two pinned siblings truncated to one ancestor
        fresh = [b for b in blocks if b not in seen_pages]
        if len(fresh) > budget:
            continue
        budget -= len(fresh)
        seen_pages.update(fresh)
        seen_keys.add(ident)
        out.append((ns, full_key, blocks))
    return out


def save_store(path: str, meta: dict, chains) -> dict:
    """Write a prefix store: ``chains`` is
    ``[(namespace, key, pages_per_leaf), ...]`` where ``pages_per_leaf``
    is one ``(stack..., n_chain_blocks, block, kv...)`` host array per
    pool leaf (block axis 1, engine layout).  Returns a summary dict."""
    arrays = {
        "meta": np.frombuffer(
            json.dumps(meta, sort_keys=True).encode(), np.uint8).copy(),
        "n_chains": np.asarray(len(chains), np.int64),
    }
    n_blocks = 0
    for i, (ns, key, pages) in enumerate(chains):
        arrays[f"ns_{i}"] = np.asarray(ns, np.int64)
        arrays[f"key_{i}"] = np.asarray(key, np.int64)
        for j, pg in enumerate(pages):
            arrays[f"pages_{i}_{j}"] = pg
        n_blocks += pages[0].shape[1] if pages else 0
    # write through a file object: np.savez_compressed appends ".npz"
    # to a bare string path, which would silently break the save/load
    # round-trip for any persist path without that suffix
    with open(path, "wb") as f:
        np.savez_compressed(f, **arrays)
    return {"chains": len(chains), "blocks": n_blocks}


def load_store(path: str, expect_meta: dict):
    """Read a prefix store written by ``save_store`` and validate its
    header against ``expect_meta`` (engine geometry + config/params
    digests).  Returns ``[(namespace, key, pages_per_leaf), ...]``.
    Raises :class:`PrefixStoreError` on any corruption or mismatch —
    the caller starts cold instead of crashing (or worse, serving
    stale KV from a different model)."""
    # normalize through JSON so tuple/list representation differences
    # between the in-memory meta and the round-tripped one never count
    # as a mismatch
    expect_meta = json.loads(json.dumps(expect_meta, sort_keys=True))
    try:
        with np.load(path) as data:
            meta = json.loads(bytes(data["meta"]).decode())
            if meta != expect_meta:
                drift = sorted(k for k in set(meta) | set(expect_meta)
                               if meta.get(k) != expect_meta.get(k))
                raise PrefixStoreError(
                    f"prefix store {path} belongs to a different engine "
                    f"configuration (mismatched: {drift})")
            chains = []
            for i in range(int(data["n_chains"])):
                key = np.asarray(data[f"key_{i}"], np.int64)
                nb = blocks_for_tokens(len(key), expect_meta["block_size"])
                pages = []
                for j, sig in enumerate(expect_meta["leaves"]):
                    shape, dtype = sig
                    pg = data[f"pages_{i}_{j}"]
                    want_dt = np.dtype(dtype)
                    if (pg.dtype != want_dt
                            and pg.dtype.itemsize == want_dt.itemsize):
                        # numpy round-trips ml_dtypes (bfloat16) arrays
                        # as raw void records — reinterpret, don't cast
                        pg = pg.view(want_dt)
                    want = (tuple(shape[:1]) + (nb,) + tuple(shape[1:]))
                    if tuple(pg.shape) != want or pg.dtype != want_dt:
                        raise PrefixStoreError(
                            f"prefix store {path}: chain {i} page tensor "
                            f"{tuple(pg.shape)}/{pg.dtype} != expected "
                            f"{want}/{dtype}")
                    pages.append(pg)
                chains.append((int(data[f"ns_{i}"]), key, pages))
            return chains
    except PrefixStoreError:
        raise
    except Exception as e:                       # corrupt zip/json/keys
        raise PrefixStoreError(
            f"prefix store {path} is unreadable: {e!r}") from e
