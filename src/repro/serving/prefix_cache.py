"""Radix-tree prefix cache over the paged KV pool.

The EdgeAI-Hub premise — shared resources across users instead of
per-device duplication — applied inside the serving engine: every
household request carries the same system/persona prefix, and with a
paged KV cache those prefix pages can be SHARED by reference instead of
re-prefilled and re-stored per request.

This module is the host-side index that makes the sharing findable: a
radix tree mapping token-id prefixes to page chains at BLOCK
granularity.  Only whole ``block_size``-token pages are ever indexed —
a shared page is by construction never written again (suffix writes
start at the next block boundary), which is what keeps sharing
zero-copy; the engine's copy-on-write guard (``KVBlockPool.fork``) is
the backstop for any path that would write a page with >1 owner.

Ownership protocol (mirrors vLLM/SGLang)
----------------------------------------
* The cache holds exactly ONE pool reference per indexed page.
* ``match(key)`` walks the tree, bumps LRU stamps, and increfs every
  matched page **on behalf of the reader** — the engine then owns those
  pages like any allocation (frees on finish, detaches on preempt).
* ``insert(key, blocks)`` adopts the caller's references for pages that
  extend the tree and returns the caller's now-duplicate ids (prefix
  already indexed under different physical pages) for the caller to
  free.  Inserting never allocates.
* ``evict(n)`` releases LRU subtrees whose pages have pool refcount 1
  (the cache is the sole owner — nothing active reads them) until ``n``
  pages went back to the free list.  Chains pinned by readers are
  skipped, so eviction can never yank KV out from under a running
  request.

Keys are ``np.int64`` sequences: plain token ids for text-only
families, with a per-request ``namespace`` (a digest of the non-token
inputs — VLM image embeds, enc-dec audio) separating subtrees whose KV
depends on more than the token ids.
"""
from __future__ import annotations

import itertools
from typing import Optional

import numpy as np

from repro.serving.kv_pool import KVBlockPool


class _Node:
    """One radix edge: ``key`` (len divisible by block_size) and the
    page chain holding its KV; children keyed by their first token."""

    __slots__ = ("key", "blocks", "children", "parent", "stamp")

    def __init__(self, key: np.ndarray, blocks: list[int],
                 parent: Optional["_Node"]):
        self.key = key
        self.blocks = blocks
        self.children: dict[int, _Node] = {}
        self.parent = parent
        self.stamp = 0


class RadixPrefixCache:
    """Block-granularity radix index of finished chains in ``pool``."""

    def __init__(self, pool: KVBlockPool, block_size: Optional[int] = None):
        self.pool = pool
        self.block_size = int(block_size or pool.block_size)
        # roots per namespace: extras-digest -> top-level node
        self._roots: dict[int, _Node] = {}
        self._clock = itertools.count(1)
        self.hits = 0
        self.misses = 0
        self.hit_blocks = 0
        self.evicted_blocks = 0
        self.inserted_blocks = 0

    # ------------------------------------------------------------------
    def _root(self, namespace: int) -> _Node:
        if namespace not in self._roots:
            self._roots[namespace] = _Node(np.zeros((0,), np.int64), [], None)
        return self._roots[namespace]

    def _common_blocks(self, edge_key: np.ndarray, key: np.ndarray,
                       pos: int) -> int:
        """Tokens of ``edge_key`` matching ``key[pos:]`` in WHOLE
        ``block_size`` units — the single definition of "shared block"
        that both match() and insert() must agree on."""
        bs = self.block_size
        lim = min(len(edge_key), len(key) - pos)
        n_eq = 0
        for j in range(0, lim - bs + 1, bs):
            if np.array_equal(edge_key[j:j + bs], key[pos + j:pos + j + bs]):
                n_eq += bs
            else:
                break
        return n_eq

    def _match_walk(self, namespace: int, key: np.ndarray):
        """Longest block-aligned match: returns (nodes touched, blocks,
        matched token count).  Pure walk — no refcounts, no stamps."""
        bs = self.block_size
        node = self._roots.get(namespace)
        if node is None:
            return [], [], 0
        nodes, blocks, matched = [node], [], 0
        pos = 0
        while pos < len(key):
            child = node.children.get(int(key[pos]))
            if child is None:
                break
            ek = child.key
            n_eq = self._common_blocks(ek, key, pos)
            if n_eq == 0:
                break
            nodes.append(child)
            blocks.extend(child.blocks[:n_eq // bs])
            matched += n_eq
            pos += n_eq
            if n_eq < len(ek):
                break                      # stopped mid-edge
            node = child
        return nodes, blocks, matched

    # ------------------------------------------------------------------
    def match(self, key, namespace: int = 0,
              max_tokens: Optional[int] = None):
        """Longest shared prefix of ``key`` already in the cache.

        Returns ``(blocks, n_tokens)`` — ``n_tokens`` is a multiple of
        ``block_size``, capped at the largest block multiple <=
        ``max_tokens`` (callers cap at ``len(prompt) - 1`` so at least
        one suffix token remains to produce admission logits).  Every
        returned page is incref'd FOR THE CALLER, and the touched nodes
        are LRU-stamped.
        """
        key = np.asarray(key, np.int64)
        bs = self.block_size
        nodes, blocks, matched = self._match_walk(namespace, key)
        if max_tokens is not None and matched > max_tokens:
            matched = (max_tokens // bs) * bs
            blocks = blocks[:matched // bs]
        if matched == 0:
            self.misses += 1
            return [], 0
        stamp = next(self._clock)
        for nd in nodes:
            nd.stamp = stamp
        self.pool.share(blocks)
        self.hits += 1
        self.hit_blocks += len(blocks)
        return list(blocks), matched

    def unrecord_hit(self, n_blocks: int) -> None:
        """Roll back one recorded hit whose chain the reader released
        WITHOUT using it (e.g. admission skipped the request this
        round and will re-match later) — keeps ``hits``/``hit_blocks``
        meaning "admissions actually served from the cache" instead of
        counting every retry of the same queued request."""
        self.hits -= 1
        self.hit_blocks -= n_blocks

    # ------------------------------------------------------------------
    def _split(self, node: _Node, at: int) -> None:
        """Split ``node``'s edge after ``at`` tokens (block multiple):
        node keeps the head, a new child gets the tail + old children."""
        bs = self.block_size
        tail = _Node(node.key[at:], node.blocks[at // bs:], node)
        tail.children = node.children
        for c in tail.children.values():
            c.parent = tail
        tail.stamp = node.stamp
        node.key = node.key[:at]
        node.blocks = node.blocks[:at // bs]
        node.children = {int(tail.key[0]): tail}

    def insert(self, key, blocks: list[int], namespace: int = 0) -> list[int]:
        """Index ``blocks`` (whole pages covering ``key``) under the
        tree, adopting the caller's pool references for pages that
        extend it.  Returns the caller's ids made redundant by an
        existing indexed prefix — the caller must free those.  ``key``
        length must equal ``len(blocks) * block_size``."""
        key = np.asarray(key, np.int64)
        bs = self.block_size
        if len(key) != len(blocks) * bs:
            raise ValueError(
                f"insert: key of {len(key)} tokens vs {len(blocks)} "
                f"blocks of {bs}")
        if not blocks:
            return []
        node = self._root(namespace)
        pos = 0
        stamp = next(self._clock)
        node.stamp = stamp
        while pos < len(key):
            child = node.children.get(int(key[pos]))
            if child is None:
                new = _Node(key[pos:], list(blocks[pos // bs:]), node)
                new.stamp = stamp
                node.children[int(key[pos])] = new
                self.inserted_blocks += len(new.blocks)
                return list(blocks[:pos // bs])     # duplicates of prefix
            n_eq = self._common_blocks(child.key, key, pos)
            child.stamp = stamp
            if n_eq < len(child.key):
                if n_eq == 0:
                    # same first token, different first block: keying
                    # them apart is impossible in a radix over first
                    # tokens — keep the resident chain, adopt nothing
                    return list(blocks)
                self._split(child, n_eq)
            pos += n_eq
            node = child
            if pos >= len(key):
                break
        return list(blocks)                          # fully duplicate

    # ------------------------------------------------------------------
    def _evictable(self, node: _Node) -> bool:
        """A subtree is evictable iff every page in it has pool
        refcount 1 (the cache's own reference) — no active reader."""
        return all(self.pool.refcount(b) == 1 for b in node.blocks) and \
            all(self._evictable(c) for c in node.children.values())

    def evictable_blocks(self) -> int:
        """Pages the cache could return to the pool RIGHT NOW (maximal
        evictable subtrees) — admission counts these as available."""
        def count(node: _Node) -> int:
            if self._evictable(node):
                return self._size(node)
            return sum(count(c) for c in node.children.values())
        return sum(count(r) for r in self._roots.values())

    @staticmethod
    def _size(node: _Node) -> int:
        return len(node.blocks) + sum(RadixPrefixCache._size(c)
                                      for c in node.children.values())

    def _leaves(self) -> list[_Node]:
        out = []

        def walk(node):
            if not node.children and node.parent is not None:
                out.append(node)
            for c in node.children.values():
                walk(c)
        for r in self._roots.values():
            walk(r)
        return out

    def evict(self, n_blocks: int) -> int:
        """Free LRU leaf chains (cache-only pages) until ``n_blocks``
        pages returned to the pool or nothing more is evictable.
        Returns the number of pages actually freed."""
        freed = 0
        while freed < n_blocks:
            leaves = [lf for lf in self._leaves()
                      if all(self.pool.refcount(b) == 1
                             for b in lf.blocks)]
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.stamp)
            self.pool.free(victim.blocks)
            freed += len(victim.blocks)
            self.evicted_blocks += len(victim.blocks)
            parent = victim.parent
            del parent.children[int(victim.key[0])]
        return freed

    # ------------------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        """Pages currently indexed (cache holds one ref each)."""
        return sum(self._size(r) for r in self._roots.values())

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "hit_blocks": self.hit_blocks,
            "cached_blocks": self.num_blocks,
            "evicted_blocks": self.evicted_blocks,
            "inserted_blocks": self.inserted_blocks,
        }

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"RadixPrefixCache(blocks={self.num_blocks}, "
                f"hits={self.hits}, misses={self.misses})")
