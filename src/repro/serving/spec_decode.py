"""Speculative decoding: draft/verify serving over the paged KV cache.

The EdgeAI-Hub's collaborative-execution idea (small resident models
backing a large one — PAPER.md §progressive inference) instantiated at
the serving layer: a cheap DRAFT model proposes ``gamma`` tokens per
slot, the big VERIFY model scores all of them in ONE paged forward
(``model.extend_paged``), and every accepted token costs the big model
1/gamma-th of a decode wave.  Decode is memory-bound, so verifying
gamma tokens in one wave is nearly the price of one — accepted drafts
are (almost) free big-model tokens.

One round, per slot (the engine batches this across slots)
----------------------------------------------------------
Let ``t0`` be the slot's pending token (``engine.tokens[slot]``, not
yet written) and ``pos`` its write frontier.

1. **Propose.**  ``gamma`` batched draft ``decode_step``s against the
   draft's own dense cache: feed ``t0`` -> sample ``d_1``, feed ``d_1``
   -> ``d_2``, ...  The last step's sample is discarded — it only
   exists so the draft's cache holds K/V for every token the verify
   feed contains (keeping draft and verify frontiers in lockstep, see
   ``advance``).
2. **Verify.**  One ``extend_paged`` over ``[t0, d_1..d_{v-1}]``
   (``v <= gamma``): row ``i`` is the big model's distribution after
   consuming the first ``i+1`` fed tokens, so row ``i-1`` judges
   proposal ``d_i`` and row ``v-1`` yields a FREE token when every
   proposal survives (the standard bonus token).
3. **Accept** (``accept_proposals``): greedy mode accepts ``d_i`` while
   it equals the verify argmax — emitted tokens are then bit-identical
   to vanilla greedy decode.  At temperature > 0 the standard
   rejection-sampling rule runs instead: accept ``d_i`` w.p.
   ``min(1, q(d_i)/p(d_i))``; on rejection sample the correction from
   ``normalize(max(q - p, 0))`` — the emitted distribution equals
   vanilla sampling from ``q`` regardless of the draft.  Always emits
   ``n_accepted + 1`` tokens (correction or bonus).
4. **Roll back.**  Rejected verify writes sit at positions > the new
   frontier, where the pre-write context mask of every subsequent
   decode/extend ignores them until they are overwritten in sequence
   order — KV rollback is bookkeeping: the engine truncates the slot to
   the accepted length and frees tail pages on block boundaries
   (``pool.assert_consistent()`` holds after every rejected run).

The draft's cache rolls back by the same masking argument when the
draft family's decode state is position-masked (fully-paged dense
trunks).  Families where that is only approximate (gemma local rings
lose evicted window entries, ssm/hybrid recurrences keep speculated
state) are still LEGAL drafts: draft state fidelity affects only the
acceptance rate, never the emitted tokens — correctness is the verify
model's alone.  The VERIFY model, by contrast, must satisfy
``model.spec_decodable`` exactly.

Self-draft mode (``ServeConfig.draft_arch="self"``) follows the
early-exit pillar (``core.earlyexit``): the draft is the verify model's
own first ``n`` layers under an exit head — no separately trained
model resident on the hub (embeddings AND the stacked trunk buffer are
shared by reference — zero duplicate device bytes; the trunk scan
slices its trip count in-trace, see ``make_self_draft``).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M

Params = Any


# ---------------------------------------------------------------------------
# host-side sampling / acceptance (shared with the engine)
# ---------------------------------------------------------------------------

def processed_dist(logits: np.ndarray, temp: float, top_k: int) -> np.ndarray:
    """The serving sampling distribution: top-k filter, then temperature
    softmax, in float64 (mirrors ``EdgeServingEngine._sample_first``)."""
    lg = np.asarray(logits, np.float64)
    if top_k and top_k > 0:
        thresh = np.sort(lg)[::-1][min(top_k, lg.size) - 1]
        lg = np.where(lg < thresh, -np.inf, lg)
    lg = lg / max(temp, 1e-6)
    lg -= lg.max()
    p = np.exp(lg)
    return p / p.sum()


def sample_from_logits(logits: np.ndarray, temp: float, top_k: int,
                       rng) -> int:
    """Greedy argmax at temp<=0, else a draw from ``processed_dist``."""
    if temp <= 0:
        return int(np.argmax(logits))
    p = processed_dist(logits, temp, top_k)
    return int(rng.choice(p.size, p=p))


def accept_greedy(proposals, argmax_row):
    """Greedy acceptance from per-row verify ARGMAX ids alone (the
    engine ships only (B, K) int32 to the host on all-greedy waves —
    full logits cross the device boundary only when some slot needs
    rejection sampling).  argmax_row: (>= len(proposals)+1,) ids.
    Returns ``(n_accepted, emitted)``, ``len(emitted) == n_accepted+1``.
    """
    emitted: list[int] = []
    for i, d in enumerate(proposals):
        if int(argmax_row[i]) != int(d):
            emitted.append(int(argmax_row[i]))
            return i, emitted
        emitted.append(int(d))
    emitted.append(int(argmax_row[len(proposals)]))
    return len(proposals), emitted


def accept_proposals(proposals, draft_dists, verify_logits: np.ndarray,
                     temp: float, top_k: int, rng):
    """Judge draft proposals against the verify logits of one round.

    proposals: ``v-1`` draft tokens ``d_1..d_{v-1}``; draft_dists: their
    sampling distributions (None entries in greedy mode);
    verify_logits: (v, V) — row ``i-1`` judges ``d_i``, row ``v-1``
    yields the bonus/correction after a clean sweep.

    Greedy (temp<=0): accept while ``d_i == argmax``; emitted tokens are
    exactly the vanilla greedy continuation.  Sampling: the standard
    rejection rule — emitted tokens are distributed exactly as vanilla
    sampling from the verify distributions.

    Returns ``(n_accepted, emitted)`` with ``len(emitted) ==
    n_accepted + 1`` (accepted prefix + correction-or-bonus).
    """
    if temp <= 0:
        return accept_greedy(proposals,
                             np.argmax(verify_logits, axis=-1))
    emitted: list[int] = []
    n_acc = 0
    for i, d in enumerate(proposals):
        q = processed_dist(verify_logits[i], temp, top_k)
        p = draft_dists[i]
        if rng.random() < min(1.0, float(q[d]) / max(float(p[d]), 1e-300)):
            emitted.append(int(d))
            n_acc += 1
            continue
        res = np.clip(q - p, 0.0, None)
        s = res.sum()
        probs = res / s if s > 0 else q
        emitted.append(int(rng.choice(probs.size, p=probs)))
        return n_acc, emitted
    # clean sweep: the last verify row is a free token
    emitted.append(sample_from_logits(verify_logits[len(proposals)],
                                      temp, top_k, rng))
    return n_acc, emitted


# ---------------------------------------------------------------------------
# draft construction / validation
# ---------------------------------------------------------------------------

def make_self_draft(cfg: ModelConfig, params: Params,
                    exit_layers: int = 0, key=None):
    """Self-draft: the verify model's first ``exit_layers`` layers under
    an early-exit head (``core.earlyexit.init_exit_heads``).  SLICE-
    FREE: the draft params reference the verify model's embedding
    tables AND its full stacked trunk buffer — zero duplicate device
    bytes; the draft config's smaller ``num_layers`` makes the trunk
    scan slice its trip count in-trace
    (``transformer._uniform_layers``), so only the exit head's norm is
    new memory.  Every model entry point (prefill / decode_step) works
    on the result unchanged.

    Supported for uniform dense/vlm stacks (``pattern_period <= 1``,
    the same restriction ``earlyexit`` carries).  Returns
    ``(draft_cfg, draft_params)``.
    """
    from repro.core.earlyexit import init_exit_heads
    if cfg.family not in ("dense", "vlm") or cfg.pattern_period > 1:
        raise ValueError(
            f"self-draft targets uniform dense/vlm stacks, not "
            f"{cfg.name} (family={cfg.family}, "
            f"pattern_period={cfg.pattern_period}); pass an explicit "
            "draft or a registry draft_arch instead")
    e = exit_layers or max(1, cfg.num_layers // 2)
    if not 1 <= e < cfg.num_layers:
        raise ValueError(f"exit_layers {e} outside [1, {cfg.num_layers})")
    heads = init_exit_heads(cfg, key if key is not None
                            else jax.random.PRNGKey(0), [e - 1])
    draft_params = dict(params)
    draft_params["trunk"] = params["trunk"]     # full stack, BY REFERENCE
    draft_params["final_norm"] = heads["exits"][0]["ln"]
    return cfg.replace(name=f"{cfg.name}-selfdraft@{e}", num_layers=e), \
        draft_params


def validate_spec(cfg: ModelConfig, draft_cfg: ModelConfig, gamma: int,
                  max_len: int) -> list[str]:
    """Draft/verify compatibility findings (empty list = compatible):
    vocab match, verify-side ``spec_decodable``, gamma bounds.  Shared
    by ``ServeConfig`` validation and ``scripts/diagnose.py --spec``."""
    problems = []
    if draft_cfg.vocab_size != cfg.vocab_size:
        problems.append(
            f"vocab mismatch: draft {draft_cfg.name} has "
            f"{draft_cfg.vocab_size}, verify {cfg.name} has "
            f"{cfg.vocab_size} — proposals would index a different "
            "token space")
    if (draft_cfg.family in ("vlm", "encdec")
            and draft_cfg.family != cfg.family):
        problems.append(
            f"draft {draft_cfg.name} (family={draft_cfg.family}) "
            "prefills from non-token extras "
            f"({'image' if draft_cfg.family == 'vlm' else 'audio'} "
            "embeds) that requests for a "
            f"{cfg.family} verify model do not carry — only a "
            "same-family draft can reuse them")
    if not M.spec_decodable(cfg):
        problems.append(
            f"verify model {cfg.name} (family={cfg.family}, "
            f"pattern_period={cfg.pattern_period}) is not spec_decodable:"
            " its decode state cannot roll back a rejected speculation")
    lo, hi = 2, max(2, max_len // 4)
    if not lo <= gamma <= hi:
        problems.append(
            f"spec_gamma {gamma} outside [{lo}, {hi}] (needs >=1 real "
            f"proposal per round and <= max_len/4 = {hi} so a round "
            "cannot span a quarter of the context)")
    return problems


# ---------------------------------------------------------------------------
# the draft runtime
# ---------------------------------------------------------------------------

class SpecDecoder:
    """Draft-model runtime for one engine: a dense decode cache with one
    row per engine slot, batched admission prefill over FULL prompts
    (the draft is cheap — it never chunks or radix-shares), and the
    per-round proposal loop.

    Frontier bookkeeping: ``draft_pos[slot]`` is the number of cache
    positions holding committed context (draft-position space: the
    draft's own image prefix, if any, plus prompt plus emitted tokens —
    the engine's pending ``tokens[slot]`` is NOT yet written on either
    side).  One round writes the whole verify feed ``[t0, d_1 ..
    d_{gamma-1}]``; ``advance(slot, n_acc)`` moves the frontier past
    the ``n_acc + 1`` of those that became context, leaving rejected
    writes stranded above the frontier where the position mask hides
    them (fully-paged drafts) or where they cost only acceptance rate
    (ring/recurrent drafts — see module docstring).
    """

    def __init__(self, draft_cfg: ModelConfig, draft_params: Params,
                 max_slots: int, max_len: int):
        # engine helpers imported lazily: engine <-> spec_decode would
        # otherwise be a module cycle (engine builds a SpecDecoder)
        from repro.serving.engine import cache_batch_axes
        self.cfg = draft_cfg
        self.params = draft_params
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = M.init_cache(draft_cfg, max_slots, max_len)
        self.axes = cache_batch_axes(draft_cfg, max_len)
        self.draft_pos = np.zeros((max_slots,), np.int32)
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,),
                               static_argnames=("need_logits",))
        self._prefills: dict[tuple, Any] = {}

    @property
    def prefix(self) -> int:
        return (self.cfg.num_image_tokens if self.cfg.family == "vlm"
                else 0)

    def _decode_fn(self, params, cache, tokens, pos,
                   need_logits: bool = False):
        """One draft step.  Greedy proposal rounds ship only the (B,)
        argmax ids; the full (B, V) logits come to the host only when
        some drafting slot samples at temperature > 0 (its proposal
        DISTRIBUTION feeds the rejection-sampling rule)."""
        logits, new_cache = M.decode_step(self.cfg, params, cache,
                                          tokens, pos)
        logits = logits[:, -1].astype(jnp.float32)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy, (logits if need_logits else None), new_cache

    # -- admission ------------------------------------------------------
    def _batch_keys(self) -> tuple:
        if self.cfg.family == "vlm":
            return ("image_embeds",)
        if self.cfg.family == "encdec":
            return ("audio_embeds",)
        return ()

    def _prefill_fn(self, bucket: int, m: int):
        key = (bucket, m)
        if key not in self._prefills:
            cfg, max_len = self.cfg, self.max_len

            def fn(params, batch, true_len):
                return M.prefill(cfg, params, batch, max_len,
                                 true_len=true_len)
            self._prefills[key] = jax.jit(fn)
        return self._prefills[key]

    def admit_group(self, reqs, slots) -> None:
        """Batched draft prefill of the FULL prompts of one admission
        group, inserted row-wise at ``slots``.  Prompts are padded to a
        shared power-of-two bucket (compile variants stay O(log
        max_len)); ``true_len`` keeps the padding exact."""
        from repro.serving.engine import extract_slot, insert_slot
        m = len(reqs)
        n_max = max(len(r.prompt) for r in reqs)
        bucket = 1 << (n_max - 1).bit_length() if n_max > 1 else 1
        bucket = min(bucket, self.max_len)     # prompts are < max_len
        prompts = np.zeros((m, bucket), np.int32)
        true_len = np.zeros((m,), np.int32)
        for i, r in enumerate(reqs):
            p = np.asarray(r.prompt, np.int32)
            prompts[i, :len(p)] = p
            prompts[i, len(p):] = p[-1]
            true_len[i] = len(p)
        batch = {"tokens": jnp.asarray(prompts)}
        for k in self._batch_keys():
            batch[k] = jnp.asarray(
                np.stack([np.asarray(r.extras[k]) for r in reqs]))
        _, rows = self._prefill_fn(bucket, m)(self.params, batch,
                                              jnp.asarray(true_len))
        for i, slot in enumerate(slots):
            one = extract_slot(rows, i, self.axes)
            self.cache = insert_slot(self.cache, one, slot, self.axes)
            self.draft_pos[slot] = self.prefix + int(true_len[i])

    # -- proposals ------------------------------------------------------
    def propose(self, spec_slots, seeds, temps, topks, gamma: int, rng):
        """``gamma`` batched draft steps.  spec_slots: slot ids drafting
        this round (other slots ride along with write-parked dummies —
        their row state is untouched at any position below their
        frontier).  Returns ``(proposals, dists)``: per spec slot,
        ``gamma - 1`` proposal tokens and their sampling distributions
        (dists hold None in greedy mode).

        Draft writes land at ``draft_pos + step`` for drafting slots so
        the round leaves K/V for the full verify feed; non-drafting
        slots park every write on one reusable position (their frontier,
        which the next real token overwrites before any read).
        """
        B = self.max_slots
        spec = np.zeros((B,), bool)
        spec[list(spec_slots)] = True
        fed = np.zeros((B, 1), np.int32)
        proposals = {s: [] for s in spec_slots}
        dists = {s: [] for s in spec_slots}
        for s in spec_slots:
            fed[s, 0] = seeds[s]
        need_logits = bool(any(temps[s] > 0 for s in spec_slots))
        for step in range(gamma):
            pos = np.where(spec, self.draft_pos + step, self.draft_pos)
            pos = np.minimum(pos, self.max_len - 1).astype(np.int32)
            greedy, logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(fed),
                jnp.asarray(pos), need_logits=need_logits)
            greedy = np.asarray(greedy)
            logits = np.asarray(logits, np.float32) if need_logits \
                else None
            for s in spec_slots:
                if step == gamma - 1:
                    continue          # last step only writes K/V
                temp, top_k = float(temps[s]), int(topks[s])
                if temp <= 0:
                    tok = int(greedy[s])
                    dists[s].append(None)
                else:
                    p = processed_dist(logits[s], temp, top_k)
                    tok = int(rng.choice(p.size, p=p))
                    dists[s].append(p)
                proposals[s].append(tok)
                fed[s, 0] = tok
        return proposals, dists

    def advance(self, slot: int, n_committed: int) -> None:
        """Move the slot's frontier past the round's committed writes
        (``n_accepted + 1`` fed tokens became context)."""
        self.draft_pos[slot] = min(self.draft_pos[slot] + n_committed,
                                   self.max_len - 1)

    # -- preemption -----------------------------------------------------
    def extract(self, slot: int) -> dict:
        """Detach the slot's draft state for ``Request.saved_state``."""
        from repro.serving.engine import extract_slot
        return {"cache": extract_slot(self.cache, slot, self.axes),
                "pos": int(self.draft_pos[slot])}

    def insert(self, slot: int, state: Optional[dict]) -> None:
        """Restore a preempted slot's draft state; with ``None`` (a
        resume that predates spec / a forced reclaim) the row keeps its
        stale content — proposals degrade, emitted tokens do not."""
        from repro.serving.engine import insert_slot
        if state is None:
            self.draft_pos[slot] = 0
            return
        self.cache = insert_slot(self.cache, state["cache"], slot,
                                 self.axes)
        self.draft_pos[slot] = state["pos"]
