"""Serving telemetry: wave/span tracing, per-request lifecycle events,
a typed metrics registry, and Perfetto / chrome://tracing export.

The EdgeAI-Hub thesis rests on *usage monitoring*: scheduling and
placement decisions need to know where time goes inside a wave and
inside a request's lifetime, not just end-of-run counters.  This module
is the zero-dependency (stdlib-only) observability spine the serving
stack reports through:

* ``MetricsRegistry`` — typed counters / gauges / histograms every
  serving subsystem registers into (``kv_pool``, ``prefix_cache``,
  speculative decoding, ``core.scheduler.plan_wave`` budgeting).  The
  engine's ``stats()`` is a *compatibility view* over this registry —
  same keys, same values as the pre-registry dicts (snapshot-tested in
  ``tests/test_telemetry.py``).  Histograms use FIXED bucket bounds so
  their shape is deterministic per config, never data-dependent.
* ``Tracer`` — span/event recorder against an injectable monotonic
  clock (``ServeConfig.trace_clock``): engine phases (admit / plan /
  draft / dispatch / device sync / retire / publish) become nested
  spans on an engine track, each slot gets its own track carrying the
  resident request's lifecycle, and per-request events (submit /
  admitted / prefill-chunk / first-token / spec-round / preempt /
  resume / CoW-fork / cancel / finish) yield an exact TTFT
  decomposition: ``queue_wait + prefill + first_wave == ttft`` by
  construction (the three segments share their boundary stamps).
* ``Tracer.dump_chrome_trace(path)`` — Chrome-trace/Perfetto JSON
  (``{"traceEvents": [...]}``): every event carries ``ph``/``ts``/
  ``pid``/``tid``, phase spans are emitted as complete ``"X"`` events
  (properly nested — they come off a per-track stack), long-lived slot
  residencies as ``"B"``/``"E"`` pairs, lifecycle marks as instants
  and per-request summaries as ``request_summary`` instants whose args
  hold the TTFT decomposition.  ``scripts/diagnose.py --trace`` reads
  a dump back and prints top-phases / per-request TTFT / acceptance-
  by-round tables.

Tracing is OFF by default (``ServeConfig.trace=False``) and
behaviour-invariant when on: the tracer only observes — traced tokens
are bit-identical to untraced runs (gated in
``benchmarks/serving_throughput.py``) and an injected deterministic
clock makes whole trace files replay-deterministic.

Clock policy: this module owns the project's monotonic clock
(``default_clock`` = ``time.perf_counter``).  Serving/launch code must
route timing through it (or through a ``Tracer``'s clock) rather than
calling ``time.time()`` — wall clock is not monotonic, and a clock
adjustment mid-run would make TTFT/ITL percentiles go negative.
``scripts/check.sh`` greps for direct ``time.time()`` /
``time.perf_counter()`` calls in ``src/`` outside this file and fails
on offenders.
"""
from __future__ import annotations

import json
from contextlib import contextmanager
from typing import Callable, Optional

import time as _time

#: The ONE monotonic clock the serving stack times against.  Injectable
#: at the Tracer level so traced runs can be replay-deterministic.
default_clock: Callable[[], float] = _time.perf_counter


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class Counter:
    """Monotonically increasing integer metric."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) < 0")
        self.value += n

    def read(self):
        return self.value


class Gauge:
    """Point-in-time value: either set directly or sampled through a
    callback at collect time (the registry stays authoritative without
    forcing every producer to push on change)."""

    __slots__ = ("name", "fn", "_value")

    def __init__(self, name: str, fn: Optional[Callable] = None):
        self.name = name
        self.fn = fn
        self._value = 0

    def set(self, value) -> None:
        if self.fn is not None:
            raise ValueError(f"gauge {self.name} is callback-sampled")
        self._value = value

    def read(self):
        return self.fn() if self.fn is not None else self._value


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are inclusive upper bounds
    (an implicit +inf bucket catches the tail).  Bounds are frozen at
    registration so the exported shape is deterministic per config —
    never a function of the observed data."""

    __slots__ = ("name", "buckets", "counts", "total", "count")

    def __init__(self, name: str, buckets):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or list(bounds) != sorted(set(bounds)):
            raise ValueError(
                f"histogram {name}: buckets must be strictly increasing, "
                f"got {buckets!r}")
        self.name = name
        self.buckets = bounds
        self.counts = [0] * (len(bounds) + 1)     # +1 = overflow bucket
        self.total = 0.0
        self.count = 0

    def observe(self, value) -> None:
        v = float(value)
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                break
        else:
            self.counts[-1] += 1
        self.total += v
        self.count += 1

    def read(self) -> dict:
        return {"buckets": list(self.buckets), "counts": list(self.counts),
                "sum": self.total, "count": self.count}


class MetricsRegistry:
    """Name -> instrument map with get-or-create semantics.

    Re-registering an existing name returns the existing instrument if
    the type matches (so subsystems can register idempotently) and
    raises on a type clash — two subsystems silently sharing a name
    with different semantics is exactly the ad-hoc-dict bug class this
    registry replaces.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, kind, factory):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, kind):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {kind.__name__}")
            return m
        m = factory()
        self._metrics[name] = m
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str, fn: Optional[Callable] = None) -> Gauge:
        g = self._get(name, Gauge, lambda: Gauge(name, fn))
        if fn is not None:
            g.fn = fn   # latest binding wins (re-attached frontends)
        return g

    def histogram(self, name: str, buckets) -> Histogram:
        return self._get(name, Histogram, lambda: Histogram(name, buckets))

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str):
        """Read one metric's current value (KeyError when absent)."""
        return self._metrics[name].read()

    def collect(self) -> dict:
        """Deterministic snapshot: ``{name: value}`` sorted by name.
        Counters/gauges read as scalars, histograms as
        ``{buckets, counts, sum, count}`` dicts."""
        return {name: self._metrics[name].read()
                for name in sorted(self._metrics)}


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

#: Track (tid) layout of a serving trace.  One process (pid 0): tid 0
#: carries the engine's per-wave phase spans, tid 1 the frontend /
#: queue-side instants, and tid SLOT_TID0 + slot the per-slot request
#: residencies.
ENGINE_TID = 0
FRONTEND_TID = 1
SLOT_TID0 = 10

_PID = 0


class Tracer:
    """Span + lifecycle-event recorder against an injectable clock.

    All timestamps are microseconds relative to construction (Chrome
    trace convention).  Phase spans (``span``) nest via a per-track
    stack and are emitted as complete ``"X"`` events; open-ended
    residencies (``begin``/``end``) emit ``"B"``/``"E"`` pairs;
    ``instant`` marks a point.  Per-request lifecycle events
    (``req_event``) are additionally kept in arrival order per uid so
    ``request_summaries()`` can compute the TTFT decomposition and ITL
    series without re-parsing the Chrome events.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = clock if clock is not None else default_clock
        self._t0 = self.clock()
        self.events: list[dict] = []
        self._stacks: dict[int, list] = {}        # tid -> open X spans
        self._open_be: dict[int, list] = {}       # tid -> open B names
        self._tracks: dict[int, str] = {ENGINE_TID: "engine",
                                        FRONTEND_TID: "frontend"}
        # uid -> [(event_name, t_us, args)] in arrival order
        self.requests: dict[int, list] = {}

    # -- time ----------------------------------------------------------
    def now_us(self) -> float:
        return (self.clock() - self._t0) * 1e6

    # -- track naming --------------------------------------------------
    def name_track(self, tid: int, name: str) -> None:
        self._tracks[tid] = name

    # -- spans ---------------------------------------------------------
    @contextmanager
    def span(self, name: str, tid: int = ENGINE_TID, **args):
        """Nested phase span (complete ``"X"`` event on exit)."""
        t0 = self.now_us()
        stack = self._stacks.setdefault(tid, [])
        stack.append(name)
        try:
            yield
        finally:
            stack.pop()
            self.events.append({
                "ph": "X", "name": name, "cat": "phase", "pid": _PID,
                "tid": tid, "ts": t0, "dur": self.now_us() - t0,
                **({"args": args} if args else {})})

    def begin(self, name: str, tid: int, **args) -> None:
        """Open-ended span (slot residency) — closed by ``end(tid)``."""
        self._open_be.setdefault(tid, []).append(name)
        self.events.append({"ph": "B", "name": name, "cat": "slot",
                            "pid": _PID, "tid": tid, "ts": self.now_us(),
                            **({"args": args} if args else {})})

    def end(self, tid: int) -> None:
        open_ = self._open_be.get(tid)
        if not open_:
            return                       # idempotent: nothing resident
        open_.pop()
        self.events.append({"ph": "E", "pid": _PID, "tid": tid,
                            "ts": self.now_us()})

    def instant(self, name: str, tid: int = ENGINE_TID, **args) -> None:
        self.events.append({"ph": "i", "s": "t", "name": name,
                            "cat": "mark", "pid": _PID, "tid": tid,
                            "ts": self.now_us(),
                            **({"args": args} if args else {})})

    def counter(self, name: str, tid: int = FRONTEND_TID, **series) -> None:
        """Chrome counter-track sample (``ph="C"``): queue depths etc."""
        self.events.append({"ph": "C", "name": name, "pid": _PID,
                            "tid": tid, "ts": self.now_us(),
                            "args": dict(series)})

    # -- per-request lifecycle -----------------------------------------
    def req_event(self, uid: int, name: str, tid: Optional[int] = None,
                  **args) -> None:
        """Record one lifecycle event for request ``uid`` and mirror it
        as an instant on ``tid`` (slot track when resident, frontend
        track otherwise)."""
        t = self.now_us()
        self.requests.setdefault(uid, []).append((name, t, args))
        self.instant(f"{name} u{uid}",
                     tid=FRONTEND_TID if tid is None else tid,
                     uid=uid, **args)

    def request_summaries(self) -> list[dict]:
        """Exact TTFT decomposition per request, from the lifecycle
        stamps:

        * ``queue_wait_us``  = submit -> admitted
        * ``prefill_us``     = admitted -> prompt_done (bucketed
          prefill, or the catch-up waves under chunked admission)
        * ``first_wave_us``  = prompt_done -> first_token

        The three segments share their boundary stamps, so they sum to
        ``ttft_us`` EXACTLY; ``e2e_us`` = submit -> finish/cancel.
        ``itl_us`` is the series of gaps between token-bearing waves,
        and ``spec_rounds`` the per-round ``(proposed, accepted)``
        pairs — per request, so a chance-level draft is visible on the
        request where it burns, not as one aggregate.
        """
        out = []
        for uid in sorted(self.requests):
            stamps: dict[str, float] = {}
            token_ts: list[float] = []
            spec_rounds: list[tuple] = []
            for name, t, args in self.requests[uid]:
                if name not in stamps:
                    stamps[name] = t     # first occurrence wins
                if name == "tokens":
                    token_ts.extend([t] * int(args.get("n", 1)))
                elif name == "spec_round":
                    spec_rounds.append((int(args.get("proposed", 0)),
                                        int(args.get("accepted", 0))))
                elif name in ("finish", "cancel"):
                    stamps["_end"] = t   # last terminal event wins
            s = stamps.get("submit")
            a = stamps.get("admitted")
            p = stamps.get("prompt_done", a)
            f = stamps.get("first_token")
            row = {"uid": uid,
                   "queue_wait_us": None if None in (s, a) else a - s,
                   "prefill_us": None if None in (a, p) else p - a,
                   "first_wave_us": None if None in (p, f) else f - p,
                   "ttft_us": None if None in (s, f) else f - s,
                   "e2e_us": (None if s is None or "_end" not in stamps
                              else stamps["_end"] - s),
                   "n_tokens": len(token_ts),
                   "itl_us": [token_ts[i] - token_ts[i - 1]
                              for i in range(1, len(token_ts))],
                   "spec_rounds": spec_rounds}
            out.append(row)
        return out

    # -- export --------------------------------------------------------
    def chrome_events(self) -> list[dict]:
        """The full Chrome-trace event list: thread-name metadata, all
        recorded events (open ``B`` residencies auto-closed at the
        current stamp), and one ``request_summary`` instant per request
        carrying its TTFT decomposition in ``args``."""
        now = self.now_us()
        events = [{"ph": "M", "name": "process_name", "pid": _PID,
                   "tid": 0, "ts": 0,
                   "args": {"name": "repro.serving"}}]
        for tid in sorted(self._tracks):
            events.append({"ph": "M", "name": "thread_name", "pid": _PID,
                           "tid": tid, "ts": 0,
                           "args": {"name": self._tracks[tid]}})
        events.extend(self.events)
        for tid, open_ in self._open_be.items():
            for _ in open_:
                events.append({"ph": "E", "pid": _PID, "tid": tid,
                               "ts": now})
        for row in self.request_summaries():
            events.append({"ph": "i", "s": "t", "name": "request_summary",
                           "cat": "summary", "pid": _PID,
                           "tid": FRONTEND_TID, "ts": now,
                           "args": row})
        return events

    def dump_chrome_trace(self, path: str) -> dict:
        """Write Perfetto-loadable Chrome-trace JSON to ``path``.
        Returns ``{"events": N, "requests": M}``."""
        events = self.chrome_events()
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"},
                      f)
            f.write("\n")
        return {"events": len(events), "requests": len(self.requests)}


# ---------------------------------------------------------------------------
# trace-file analysis (shared by scripts/diagnose.py --trace)
# ---------------------------------------------------------------------------

def validate_chrome_trace(events: list) -> list[str]:
    """Structural findings for a Chrome-trace event list (empty = ok):
    every event must carry ``ph``/``ts``/``pid``/``tid``, ``X`` events
    a non-negative ``dur``, and ``B``/``E`` pairs must balance per
    track — the properties Perfetto needs to lay the tracks out."""
    problems = []
    depth: dict[tuple, int] = {}
    for i, ev in enumerate(events):
        for k in ("ph", "ts", "pid", "tid"):
            if k not in ev:
                problems.append(f"event {i} missing {k!r}: {ev}")
        ph = ev.get("ph")
        if ph == "X" and ev.get("dur", -1) < 0:
            problems.append(f"event {i}: X without non-negative dur")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            depth[key] = depth.get(key, 0) + 1
        elif ph == "E":
            depth[key] = depth.get(key, 0) - 1
            if depth[key] < 0:
                problems.append(f"event {i}: E without matching B on "
                                f"track {key}")
    for key, d in sorted(depth.items()):
        if d > 0:
            problems.append(f"track {key}: {d} unclosed B span(s)")
    return problems


def summarize_trace(trace: dict) -> dict:
    """Aggregate a loaded Chrome-trace dict (``dump_chrome_trace``
    output): top phases by total time, per-request TTFT decomposition
    rows (from the ``request_summary`` instants) and speculative
    acceptance by round ordinal."""
    events = trace.get("traceEvents", trace if isinstance(trace, list)
                       else [])
    phases: dict[str, list] = {}
    summaries = []
    for ev in events:
        if ev.get("ph") == "X":
            agg = phases.setdefault(ev.get("name", "?"), [0.0, 0])
            agg[0] += float(ev.get("dur", 0.0))
            agg[1] += 1
        elif ev.get("name") == "request_summary":
            summaries.append(ev.get("args", {}))
    by_round: dict[int, list] = {}
    for row in summaries:
        for j, (prop, acc) in enumerate(row.get("spec_rounds", ())):
            agg = by_round.setdefault(j, [0, 0])
            agg[0] += prop
            agg[1] += acc
    return {
        "problems": validate_chrome_trace(events),
        "phases": sorted(
            ({"name": n, "total_us": t, "calls": c,
              "mean_us": t / max(c, 1)} for n, (t, c) in phases.items()),
            key=lambda r: -r["total_us"]),
        "requests": summaries,
        "accept_by_round": {j: {"proposed": p, "accepted": a,
                                "rate": a / max(p, 1)}
                            for j, (p, a) in sorted(by_round.items())},
    }
