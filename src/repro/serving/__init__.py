"""EdgeAI-Hub serving runtime — continuous batching for one model.

Admission semantics (the contract tests rely on)
------------------------------------------------
* **Paged KV cache.** Global attention layers store K/V in a shared
  pool of ``ServeConfig.kv_block_size``-token pages
  (``serving.kv_pool.KVBlockPool`` + ``models.layers.init_kv_pages``)
  instead of one dense ``max_len`` strip per slot; each slot's ordered
  page list is mirrored to the device as an int32 block table consumed
  by ``model.decode_step_paged``.  Admission is capacity-aware (enough
  FREE PAGES, not merely a free slot), decode appends a page on block
  boundary crossing, and on pool exhaustion a slot is preempted back to
  the queue with its pages detached (preempt-or-queue — no deadlock;
  ``run_until_drained`` force-reclaims a detached holder only when
  nothing else can run).  The logical page view equals ``max_len``, so
  paged decode is bit-for-bit identical to the dense path
  (``ServeConfig.paged=False``) — the engine's memory ceiling drops
  from ``max_slots x max_len`` strips to actual tokens in flight.
  Local ring-window layers stay dense at ``W``; SSM state is O(1);
  families with no global KV (ssm, hybrid) run dense with zero pool
  demand.
* **Shared-prefix radix cache.** Admission prefill writes prompt K/V
  DIRECTLY into pages (``model.prefill_paged`` — no dense strip is
  shadow-copied), and finished chains are returned to a radix index
  (``serving.prefix_cache.RadixPrefixCache``) instead of freed.  A
  later request with the same prefix shares those pages by reference
  and prefills only its unmatched suffix at the chain's end position —
  the common household system/persona prompt is prefilled ONCE per
  hub, not once per request.  Matching is TOKEN-granular: a hit may
  end mid-page (divergence inside a cached page, or a chain's indexed
  partial tail); admission CoW-forks that one page
  (``KVBlockPool.fork`` + device copy, ``_cow_guard`` as the per-wave
  backstop) and the suffix prefill writes from the matched token
  onward.  Sharing is also IN-FLIGHT: every committed wave publishes
  each live slot's pages below its frontier into the tree
  (``_publish_frontiers``), so concurrent same-prefix tenants share a
  chain that is still decoding — readers pin strictly below the
  frontier, writers and spec-decode rollback only touch at/above it.
  With ``ServeConfig.prefix_persist_path``, ``engine.close()``
  PERSISTS the hot refcount-free chains (keys + page bytes) to a
  host-side store and a restarted engine rehydrates them for
  warm-TTFT hits; corrupt/mismatched stores are rejected cleanly.
  Sharing is behaviour-invariant (hit decode — token-granular,
  in-flight or restart-warm — is bit-identical to cold, verified per
  family) and only engages where the full decode state lives in pages
  (``model.prefix_sharable``); LRU chains are evicted under pool
  pressure, never from under a reader.
* **Exact padded prefill.** Prompts are right-padded to the smallest
  ``ServeConfig.prefill_buckets`` entry that fits and prefilled batched
  per bucket.  ``model.prefill(..., true_len=)`` makes the padding
  semantically invisible: admission logits come from the true last
  prompt token, pad positions never enter the KV/ring/SSM state, and
  the slot position starts at ``prefix + true_len`` (prefix = VLM image
  tokens) — NOT at the bucket size.  A non-bucket-aligned prompt decodes
  token-for-token identically to an unpadded single-request run
  (``tests/test_decode_consistency.py::test_padded_admission_matches_reference``).
  One carve-out: MoE expert *capacity* is derived from the static
  (padded, batched) token count, so under capacity pressure the set of
  dropped tokens can differ from an unpadded run — pads never steal
  capacity slots (they route to a sentinel expert), but the capacity
  bound itself is shape-derived.  With ``capacity_factor`` high enough
  that nothing drops, MoE is bit-exact like every other family.
* **Chunked prefill.** Prompts longer than the largest bucket prefill
  their first ``max(prefill_buckets)`` tokens, then catch up through the
  shared batched decode wave (teacher-forced, sampled outputs
  discarded) — long-prompt admission never stalls the other tenants in
  the batch.  With ``ServeConfig.chunked_prefill`` the bucketed call
  disappears entirely for token-only requests: admission is pure
  bookkeeping and the WHOLE prompt catches up as wave spans of up to
  ``catch_chunk`` tokens, planned against decode/spec slots under the
  ``wave_tokens`` per-wave budget (``core.scheduler.plan_wave``) —
  Sarathi-style mixed waves, step-driven with no drain assumption
  (``tests/test_engine_matrix.py`` gates the chunked axis
  token-identical to a chunked dense vanilla engine).
* **QoE admission order.** The queue is ranked by
  ``core.scheduler.admission_rank`` (fifo | priority | edf via
  ``ServeConfig.policy``) — the same policy definition the hub's
  discrete-event scheduler simulates.  Under pool pressure the feasible
  subset is admitted in rank order (infeasible requests wait, they are
  never dropped).
* **Per-request sampling.** ``Request.temperature`` / ``Request.top_k``
  override engine defaults inside the jitted decode step.
* **Speculative decoding.** ``ServeConfig.spec_decode`` turns every
  wave into a draft/verify round (``serving.spec_decode``): a resident
  draft model proposes ``spec_gamma - 1`` tokens per slot and ONE
  ``model.extend_paged`` call verifies them all — greedy output is
  bit-identical to vanilla decode, temperature > 0 uses rejection
  sampling (emitted distribution equals vanilla sampling), and a
  rejected run rolls back by masking + tail-page free
  (``pool.assert_consistent`` holds after every drain_step).  Gated to
  ``model.spec_decodable`` configs, exactly like the prefix cache —
  on both engines (the dense ``paged=False`` twin speculates
  wave-for-wave identically); the same ``extend_paged``/``extend``
  path retires the old 1-token-per-step catch-up prefill on every
  attention family.
* **int8 paged KV (quantized serving).** ``ServeConfig.quant_kv="int8"``
  stores pool pages as int8 with one f32 symmetric scale per
  (page, token offset, kv head) head_dim vector — extra
  ``k_scale``/``v_scale`` pool leaves of shape
  ``(num_blocks, block_size, kv_heads)``, a ~``4/head_dim`` overhead
  that shrinks page bytes ~3.8x at head_dim 64
  (``serving.kv_pool.page_bytes``) and raises the admission ceiling by
  the same factor at fixed HBM.  Quantization happens ON WRITE
  (``models.layers.scatter_kv_pages`` / ``scatter_kv_tokens``) so a
  committed page is never re-scaled — the write-once invariant CoW,
  rollback and in-flight sharing rely on is untouched, and every
  generic page machinery path (CoW copies, chain serialization,
  persistence, preemption) covers the scale leaves automatically
  because they are ordinary pool leaves.  Reads dequantize either by
  gather (jnp path) or FUSED inside the Pallas paged decode/extend
  kernels (``use_pallas_paged`` — ``kernels.flash_attention``
  ``paged_attention`` / ``paged_extend_attention`` with
  ``k_scale``/``v_scale``).  Decode is NOT bit-exact vs f32: the
  engine-matrix gates it tolerance-based (longest-common-prefix +
  first-token agreement vs the dense vanilla reference), while
  quant-vs-quant restart-warm persistence stays bit-identical and a
  store header pins the quant layout (f32<->int8 stores are rejected
  "mismatched", the engine starts cold).  ``quant_draft=True``
  additionally serves a separate draft model with int8 weights via
  ``models.layers.quantize_matmul_params``/``weight_einsum`` (TPU:
  the ``quant_matmul`` Pallas kernel) — greedy spec output remains
  bit-exact because the f32 verify trunk decides every token; a
  quantized draft can only change acceptance rate.  Families without
  paged KV (ssm, hybrid) accept ``quant_kv`` and serve dense
  unquantized (``engine.quant`` reports the armed state).
* **KV-preserving preemption.** ``preempt()`` extracts the slot's dense
  cache leaves and decode position onto ``Request.saved_state`` and
  detaches its KV pages (refcounts held, zero copies); re-submission
  restores the block table — no re-prefill, bit-identical continuation.
  ``submit`` rejects resumed states that could not make progress.

* **Telemetry.** ``serving.telemetry`` is the observability spine:
  every subsystem registers typed counters/gauges/histograms into the
  engine's ``MetricsRegistry`` (``engine.metrics``; ``stats()`` is a
  compatibility view over it) and ``ServeConfig.trace=True`` records
  wave phases + per-request lifecycles against an injectable monotonic
  clock, exported as Perfetto/chrome://tracing JSON via
  ``engine.dump_chrome_trace`` / ``launch.serve --trace`` and
  summarized by ``scripts/diagnose.py --trace``.  Tracing is
  behaviour-neutral (traced tokens bit-identical to untraced — gated
  in ``benchmarks/serving_throughput.py``).

Counter/metric glossary
-----------------------
``stats()`` key (registry name in parens), one line each.

Engine (always present):

* ``steps`` (``engine.steps``) — committed engine waves (prefill
  admissions + decode/extend steps).
* ``peak_active`` (``engine.peak_active``) — max concurrently resident
  requests observed.
* ``peak_pool_used`` (``engine.peak_pool_used``) — max KV pages in
  flight at once.
* ``exhaust_preempts`` (``engine.exhaust_preempts``) — slots preempted
  because the pool ran out of pages mid-decode.
* ``reclaims`` (``engine.reclaims``) — forced reclaims of a detached
  preempted holder to un-wedge admission.
* ``cow_forks`` (``engine.cow_forks``) — copy-on-write page forks
  (mid-page hit tails + in-flight shared frontier writes).
* ``mixed_waves`` (``engine.mixed_waves``) — waves mixing catch-up
  prefill spans with decode/spec slots (chunked prefill).
* ``wave_admitted`` (``engine.wave_admitted``) — requests admitted via
  the zero-prefill chunked path (bookkeeping-only admission).
* ``cancels`` (``engine.cancels``) — requests cancelled mid-flight.
* ``published_frontiers`` (``engine.published_frontiers``; prefix
  configs) — per-wave publications of live chains into the radix index.

KV pool (paged configs; ``kv_pool.*``):

* ``pool_blocks`` (``kv_pool.blocks``) — total physical pages.
* ``pool_free`` (``kv_pool.free``) — pages on the free list now.
* ``pool_shared`` (``kv_pool.shared``) — pages with refcount > 1 now.
* registry-only: ``kv_pool.used`` (allocated pages now),
  ``kv_pool.alloc_blocks`` / ``kv_pool.share_blocks`` /
  ``kv_pool.fork_copies`` / ``kv_pool.reclaimed_blocks`` — cumulative
  page traffic (allocations, reference shares, CoW copies, returns).

Prefix cache (``prefix_cache.*``; prefix configs):

* ``prefix_hits`` / ``prefix_misses`` / ``prefix_hit_rate`` — match
  outcomes at admission (hits actually served).
* ``prefix_hit_blocks`` / ``prefix_hit_tokens`` — pages / tokens served
  by reference instead of re-prefilled.
* ``prefix_hit_tokens_block`` — block-granular counterfactual of
  ``prefix_hit_tokens`` (the token-granularity gain is the delta).
* ``prefix_cached_blocks`` — pages currently indexed in the radix tree.
* ``prefix_evicted_blocks`` / ``prefix_inserted_blocks`` /
  ``prefix_replaced_blocks`` — LRU evictions, chain insertions, partial
  tails superseded by longer chains.
* ``prefix_short_matches`` — matches rejected by the admission floor
  (``min_match_tokens``).
* registry-only: ``prefix_cache.hit_tokens_hist`` — histogram of
  matched tokens per served hit.

Speculative decoding (``spec.*``; spec configs):

* ``spec_active`` (``spec.active``) — a draft model is resident.
* ``spec_steps`` / ``spec_rounds`` — waves that speculated / per-slot
  draft-verify rounds.
* ``spec_proposed`` / ``spec_accepted`` / ``spec_emitted`` — draft
  tokens proposed, accepted, and big-model tokens emitted (accepted +
  the free verify token).
* ``spec_acceptance`` — accepted / proposed.
* ``spec_tokens_per_round`` — emitted / rounds (1.0 = vanilla pace).
* registry-only: ``spec.depth{j}.proposed`` / ``.accepted`` —
  acceptance by draft depth j within a round (decays with depth; the
  signal that picks gamma).

Quantized serving (``quant.*``; quant configs):

* ``quant_kv`` (``quant.kv``) — KV pool dtype ("" = f32).
* ``quant_draft`` (``quant.draft``) — int8-weight draft is serving.
* ``quant_page_bytes`` / ``quant_f32_page_bytes`` — device bytes of one
  page under this layout vs f32 (the capacity lever).

Prefix persistence (``persist.*``; persist configs):

* ``persist_loaded_chains`` / ``persist_loaded_blocks`` — chains/pages
  rehydrated from the store at startup.
* ``persist_spilled_chains`` — chains spilled to the store under pool
  pressure this run.
* ``persist_rejected`` — non-empty reason when a store was rejected
  (corrupt / config mismatch) and the engine started cold.

Scheduler (registry-only; budgeted waves):

* ``sched.budget_utilization`` — histogram of granted/budget per
  planned wave.
* ``sched.demotions`` — slots granted less width than they wanted.

Frontend (registry-only; ``launch.serve.AsyncServingFrontend``):

* ``frontend.steps`` / ``frontend.streams`` / ``frontend.inbox_depth``
  / ``frontend.pending_cancels`` — loop progress and queue depths.

JAX version compatibility: all version-sensitive jax.sharding / mesh
symbols are imported via ``repro.compat`` (see its module docstring for
the shim policy); ``scripts/check.sh`` runs an import sweep that
catches version breaks at import time.
"""
from repro.serving.engine import (
    EdgeServingEngine,
    Request,
    ServeConfig,
    cache_batch_axes,
    extract_slot,
    insert_slot,
    paged_cache_axes,
)
from repro.serving.kv_pool import KVBlockPool, PoolExhausted, \
    blocks_for_tokens
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.spec_decode import (SpecDecoder, accept_proposals,
                                       make_self_draft, validate_spec)
from repro.serving.telemetry import (MetricsRegistry, Tracer,
                                     default_clock, summarize_trace,
                                     validate_chrome_trace)

__all__ = ["EdgeServingEngine", "Request", "ServeConfig",
           "cache_batch_axes", "extract_slot", "insert_slot",
           "paged_cache_axes", "KVBlockPool", "PoolExhausted",
           "blocks_for_tokens", "RadixPrefixCache", "SpecDecoder",
           "accept_proposals", "make_self_draft", "validate_spec",
           "MetricsRegistry", "Tracer", "default_clock",
           "summarize_trace", "validate_chrome_trace"]
