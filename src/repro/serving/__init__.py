from repro.serving.engine import (
    EdgeServingEngine,
    Request,
    ServeConfig,
    cache_batch_axes,
    insert_slot,
)

__all__ = ["EdgeServingEngine", "Request", "ServeConfig",
           "cache_batch_axes", "insert_slot"]
