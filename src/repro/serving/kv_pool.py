"""Paged KV-cache block pool for the serving engine.

The dense engine reserved one ``max_len``-long KV strip per slot, so
HBM — not compute — capped concurrency at ``max_slots`` regardless of
how short the resident requests actually were.  This module provides the
block-granular allocator that converts that ceiling into *actual tokens
in flight*: physical KV pages of ``block_size`` tokens live in one
shared pool (``models.layers.init_kv_pages``), and each request owns an
ordered list of block ids — its *block table* — mapping logical token
blocks to physical pages.

Host-side bookkeeping only: the pool tracks free ids and refcounts; the
device-side page tensors are owned by the engine's cache pytree and are
indexed by the block tables this allocator hands out.

Semantics
---------
* ``alloc(n)`` pops ``n`` ids off a LIFO free list (fixed-size blocks
  mean reuse is fragmentation-free by construction) with refcount 1, or
  raises :class:`PoolExhausted` without side effects.
* ``free(ids)`` decrements refcounts and returns ids whose count hits
  zero to the free list.
* ``incref(ids)`` / ``share(ids)`` support shared pages (detached
  preempted requests, radix prefix-cache chains): a page is reclaimed
  only when every owner has released it.
* ``fork(id)`` is the copy-on-write primitive: before WRITING to a page
  some other owner can still read, the writer trades its reference for
  a fresh private page (the caller copies the device bytes); a page
  with a single owner is returned unchanged — no copy, no alloc.
* ``assert_consistent()`` is the accounting invariant every engine stats
  path checks: free + refcounted == total, and no free page holds a
  reference.  Any alloc/share/fork/free interleaving must preserve it.
"""
from __future__ import annotations

import numpy as np


class PoolExhausted(RuntimeError):
    """Raised by ``alloc`` when fewer free blocks exist than requested."""


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Number of ``block_size``-token pages covering ``n_tokens``."""
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // block_size)


def page_bytes(cfg, block_size: int, kv_dtype=None) -> int:
    """Device bytes of ONE physical page across all stacked pool layers
    for the given quant layout.

    f32 layout: K and V at 4 bytes/element.  ``kv_dtype="int8"``: K/V at
    1 byte plus one f32 scale per (token offset, kv head) — an overhead
    of ``4 / head_dim`` relative to the int8 bytes, so the page shrinks
    ~3.8x at head_dim 64 (the capacity lever the admission ceiling
    sees).  Only GLOBAL attention layers hold pages; callers that mix
    dense ring layers (gemma patterns) account those separately.
    """
    n_global = sum(1 for i in range(cfg.num_layers)
                   if cfg.pattern_period <= 1
                   or (i + 1) % cfg.pattern_period == 0)
    per_tok = block_size * cfg.num_kv_heads
    if kv_dtype == "int8":
        elem = per_tok * cfg.head_dim * 1 + per_tok * 4   # int8 + f32 scale
    elif kv_dtype is None:
        elem = per_tok * cfg.head_dim * 4
    else:
        raise ValueError(f"unknown kv_dtype {kv_dtype!r}")
    return 2 * elem * max(n_global, 1)                     # K and V


def pool_blocks_for_budget(cfg, block_size: int, budget_bytes: int,
                           kv_dtype=None) -> int:
    """How many pool pages fit in ``budget_bytes`` of device memory for
    the given quant layout — the fixed-HBM capacity comparison the
    quantized-serving benchmark reports (int8 vs f32 concurrent slots
    at identical pool bytes)."""
    pb = page_bytes(cfg, block_size, kv_dtype)
    return max(0, int(budget_bytes) // pb)


class KVBlockPool:
    """Fixed-size KV page allocator with refcounts (host-side)."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # LIFO: freshly freed pages are reused first (cache-warm reuse)
        self._free: list[int] = list(range(self.num_blocks - 1, -1, -1))
        self._refcount = np.zeros(self.num_blocks, np.int32)
        # traffic counters, live only after attach_metrics (telemetry)
        self._m_alloc = self._m_share = None
        self._m_fork = self._m_reclaim = None

    # ------------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def num_shared(self) -> int:
        """Pages with more than one owner right now — prefix-cache
        chains pinned by readers, in-flight published frontiers,
        detached preemption twins.  Observability for how much KV the
        sharing machinery is actually deduplicating."""
        return int((self._refcount > 1).sum())

    def refcount(self, block_id: int) -> int:
        return int(self._refcount[block_id])

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def attach_metrics(self, registry) -> None:
        """Register this pool's occupancy gauges and traffic counters
        into a ``serving.telemetry.MetricsRegistry``: occupancy
        (``kv_pool.blocks/free/used/shared``) samples the live pool at
        collect time; traffic (``kv_pool.alloc/share/fork_copy/
        reclaimed_blocks``) counts page movements, bumped by
        alloc/share/fork/free themselves."""
        registry.gauge("kv_pool.blocks", lambda: self.num_blocks)
        registry.gauge("kv_pool.free", lambda: self.num_free)
        registry.gauge("kv_pool.used", lambda: self.num_used)
        registry.gauge("kv_pool.shared", lambda: self.num_shared)
        self._m_alloc = registry.counter("kv_pool.alloc_blocks")
        self._m_share = registry.counter("kv_pool.share_blocks")
        self._m_fork = registry.counter("kv_pool.fork_copies")
        self._m_reclaim = registry.counter("kv_pool.reclaimed_blocks")

    # ------------------------------------------------------------------
    def alloc(self, n: int) -> list[int]:
        """Claim ``n`` blocks (refcount 1 each) or raise PoolExhausted.

        All-or-nothing: on failure the pool is untouched, so admission
        can probe feasibility without cleanup.
        """
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} blocks, only {len(self._free)} of "
                f"{self.num_blocks} free")
        ids = [self._free.pop() for _ in range(n)]
        self._refcount[ids] += 1
        if self._m_alloc is not None:
            self._m_alloc.inc(n)
        return ids

    def incref(self, block_ids) -> None:
        for b in block_ids:
            if self._refcount[b] <= 0:
                raise ValueError(f"incref on unallocated block {b}")
            self._refcount[b] += 1
            if self._m_share is not None:
                self._m_share.inc()

    # prefix sharing reads as "share these pages with one more owner"
    share = incref

    def fork(self, block_id: int) -> int:
        """Copy-on-write: give the caller a PRIVATE page id in exchange
        for its reference on ``block_id``.

        With refcount 1 the caller already owns the page exclusively —
        it is returned unchanged.  Otherwise one fresh page is allocated
        (refcount 1), the caller's reference on the shared page is
        dropped, and the new id is returned; the caller is responsible
        for copying the device-side page contents old -> new.  Raises
        :class:`PoolExhausted` (pool untouched) when no page is free.
        """
        if self._refcount[block_id] <= 0:
            raise ValueError(f"fork of unallocated block {block_id}")
        if self._refcount[block_id] == 1:
            return int(block_id)
        (new,) = self.alloc(1)
        self._refcount[block_id] -= 1
        if self._m_fork is not None:
            self._m_fork.inc()
        return new

    def free(self, block_ids) -> None:
        """Release one reference per id; zero-ref pages return to the
        free list (in order, so tests can assert deterministic reuse)."""
        for b in block_ids:
            if self._refcount[b] <= 0:
                raise ValueError(f"double free of block {b}")
            self._refcount[b] -= 1
            if self._refcount[b] == 0:
                self._free.append(int(b))
                if self._m_reclaim is not None:
                    self._m_reclaim.inc()

    # ------------------------------------------------------------------
    def assert_consistent(self) -> None:
        """Accounting invariant: every page is either on the free list
        (refcount 0) or referenced (refcount > 0) — never both, never
        neither.  Raises RuntimeError with the drift details."""
        n_ref = int((self._refcount > 0).sum())
        if len(self._free) + n_ref != self.num_blocks:
            raise RuntimeError(
                f"pool accounting drift: free {len(self._free)} + "
                f"refcounted {n_ref} != total {self.num_blocks}")
        if len(set(self._free)) != len(self._free):
            raise RuntimeError("pool free list contains duplicates")
        bad = [b for b in self._free if self._refcount[b] != 0]
        if bad:
            raise RuntimeError(f"free blocks with live refcount: {bad}")

    # ------------------------------------------------------------------
    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"KVBlockPool(blocks={self.num_blocks}, "
                f"block_size={self.block_size}, free={self.num_free})")
