"""JAX version-portability layer.

Policy (see also ROADMAP.md §Open items)
----------------------------------------
* Minimum supported JAX: 0.4.30 (first release with ``jax.tree.map`` and
  the ``jax.sharding`` module layout this repo relies on).
* Anything newer than the minimum is OPTIONAL: modern symbols are probed
  with guarded imports at module load and shimmed when absent.  Code in
  this repo must import version-sensitive sharding/mesh symbols from
  ``repro.compat`` — never from ``jax``/``jax.sharding`` directly — so a
  version break surfaces HERE, once, instead of scattered ImportErrors.
* To add a shim: probe the modern symbol in a try/except ImportError (or
  a signature check), provide a fallback with the same call surface, and
  record the result in ``_SHIMS`` so ``report()`` (surfaced by
  ``scripts/diagnose.py`` and ``scripts/check.sh``) shows what is active.

Shimmed surface
---------------
``AxisType``          enum (jax>=0.6 ``jax.sharding.AxisType``); a
                      stand-in enum with ``Auto``/``Explicit``/``Manual``
                      members on older versions.
``make_mesh(...)``    ``jax.make_mesh`` accepting ``axis_types`` — the
                      kwarg is dropped where unsupported (axis types only
                      change tracing-time sharding inference, not the
                      mesh itself).
``abstract_mesh(shape, names)``
                      version-stable ``AbstractMesh`` constructor: newer
                      JAX takes ``(axis_sizes, axis_names)``, 0.4.x takes
                      a ``((name, size), ...)`` tuple.
``Mesh / NamedSharding / PartitionSpec / AbstractMesh``
                      re-exports so callers have one import site.
"""
from __future__ import annotations

import enum
import inspect

import jax
from jax.sharding import (  # noqa: F401  (re-exports)
    AbstractMesh,
    Mesh,
    NamedSharding,
    PartitionSpec,
)

JAX_VERSION: tuple = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit())
MIN_SUPPORTED: tuple = (0, 4, 30)

_SHIMS: dict = {}  # name -> "native" | "shimmed"


# ---------------------------------------------------------------------------
# AxisType
# ---------------------------------------------------------------------------

try:
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    _SHIMS["AxisType"] = "native"
except ImportError:
    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` (jax >= 0.6).

        On versions without explicit axis types every mesh axis already
        behaves as ``Auto``, so carrying the enum value is enough for
        call-site compatibility.
        """
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"
    _SHIMS["AxisType"] = "shimmed"


# ---------------------------------------------------------------------------
# make_mesh
# ---------------------------------------------------------------------------

def _native_make_mesh_kwargs() -> set:
    if not hasattr(jax, "make_mesh"):
        return set()
    try:
        return set(inspect.signature(jax.make_mesh).parameters)
    except (TypeError, ValueError):
        return set()


_MAKE_MESH_KWARGS = _native_make_mesh_kwargs()
_SHIMS["make_mesh"] = (
    "native" if "axis_types" in _MAKE_MESH_KWARGS else "shimmed")


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates missing ``axis_types`` support."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and "axis_types" in _MAKE_MESH_KWARGS:
        kwargs["axis_types"] = axis_types
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
    # pre-0.4.35 fallback: build the device ndarray by hand
    from jax.experimental import mesh_utils
    devs = mesh_utils.create_device_mesh(tuple(axis_shapes), devices=devices)
    return Mesh(devs, tuple(axis_names))


# ---------------------------------------------------------------------------
# AbstractMesh
# ---------------------------------------------------------------------------

def _abstract_mesh_convention() -> str:
    """'modern' = AbstractMesh(axis_sizes, axis_names);
    'legacy' = AbstractMesh(((name, size), ...))."""
    try:
        params = list(inspect.signature(AbstractMesh).parameters)
    except (TypeError, ValueError):
        return "modern"
    return "legacy" if params and params[0] == "shape_tuple" else "modern"


_ABSTRACT_CONVENTION = _abstract_mesh_convention()
_SHIMS["abstract_mesh"] = (
    "native" if _ABSTRACT_CONVENTION == "modern" else "shimmed")


def abstract_mesh(axis_shapes, axis_names) -> AbstractMesh:
    """Version-stable ``AbstractMesh((16, 16), ("data", "model"))``."""
    if _ABSTRACT_CONVENTION == "modern":
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


# ---------------------------------------------------------------------------
# compiled-artifact introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every version.

    JAX <= 0.4.x returns a one-element list of per-program dicts; newer
    versions return the dict directly.  Either way ``{}`` when XLA
    provides nothing.
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


_SHIMS["cost_analysis"] = "normalized"


# ---------------------------------------------------------------------------
# diagnostics
# ---------------------------------------------------------------------------

def report() -> dict:
    """Machine-readable shim status (printed by scripts/diagnose.py)."""
    return {
        "jax_version": jax.__version__,
        "min_supported": ".".join(map(str, MIN_SUPPORTED)),
        "supported": JAX_VERSION >= MIN_SUPPORTED,
        "shims": dict(_SHIMS),
    }
