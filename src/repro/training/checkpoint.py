"""Checkpointing: pytree <-> .npz with path-keyed arrays + JSON metadata.

Works for any params/opt-state pytree (dict-of-dicts with array leaves).
Distributed note: callers gather to host before saving (the launcher
does this per-process); restore re-shards via device_put with the
step's shardings.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{SEP}"))
    else:
        out[prefix.rstrip(SEP)] = tree
    return out


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    arrays = {}
    meta = {"leaves": {}, "user": metadata or {}}
    for k, v in flat.items():
        arr = np.asarray(v)
        if arr.dtype == jnp.bfloat16:
            meta["leaves"][k] = "bfloat16"
            arr = arr.astype(np.float32)
        arrays[k] = arr
    np.savez(path, **arrays)
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes must match)."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as z:
        data = {k: z[k] for k in z.files}
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    bf16 = set()
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            bf16 = {k for k, v in json.load(f)["leaves"].items()
                    if v == "bfloat16"}

    flat_like = _flatten(like)
    out = {}
    for k, ref in flat_like.items():
        arr = data[k]
        if k in bf16:
            arr = arr.astype(jnp.bfloat16)
        if arr.shape != np.shape(ref):
            raise ValueError(f"shape mismatch at {k}: "
                             f"{arr.shape} vs {np.shape(ref)}")
        out[k] = jnp.asarray(arr)
    return _unflatten_like(like, out)


def _unflatten_like(like: Any, flat: dict, prefix: str = "") -> Any:
    if isinstance(like, dict):
        return {k: _unflatten_like(like[k], flat, f"{prefix}{k}{SEP}")
                for k in like}
    if isinstance(like, (list, tuple)):
        vals = [_unflatten_like(v, flat, f"{prefix}{i}{SEP}")
                for i, v in enumerate(like)]
        return type(like)(vals)
    return flat[prefix.rstrip(SEP)]
