from repro.training import checkpoint, federated, optimizer, trainer
from repro.training.optimizer import OptimizerConfig
from repro.training.trainer import TrainConfig, make_train_step

__all__ = ["OptimizerConfig", "TrainConfig", "checkpoint", "federated",
           "make_train_step", "optimizer", "trainer"]
