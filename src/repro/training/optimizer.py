"""Optimizers: AdamW with optional 8-bit (block-quantized) moments.

No external deps (optax is not available offline) — implemented directly.
The 8-bit moment store is a sustainability/memory lever (DESIGN.md
§Sustainable-AI): it quarters optimizer HBM, which is what decides
whether the trillion-parameter paper-table MoE fits the mesh at all
(EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Params = Any
BLOCK = 256  # quantization block size for 8-bit moments


@dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moments_dtype: str = "float32"  # "float32" | "int8"


def lr_schedule(cfg: OptimizerConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    frac = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * frac


# ---------------------------------------------------------------------------
# 8-bit block quantization for moment tensors
# ---------------------------------------------------------------------------

def _q8_encode(x: jnp.ndarray):
    """SHAPE-PRESERVING block quantization along the last axis.

    q keeps the parameter's shape (padded on the last axis to a BLOCK
    multiple) so the optimizer-state sharding can MIRROR the parameter
    sharding exactly — a flattened layout forces XLA to reshard/
    replicate f32 moments of every update (the 1T-MoE pathology:
    2.4 TB/chip temps).  scale is one f32 per BLOCK of the last axis.
    """
    *lead, last = x.shape
    pad = (-last) % BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    blocks = x.reshape(*lead, (last + pad) // BLOCK, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(*lead, last + pad),
            "scale": scale.astype(jnp.float32)}


def _q8_decode(enc, shape, size) -> jnp.ndarray:
    del size
    *lead, last = shape
    padded = enc["q"].shape[-1]
    blocks = enc["q"].reshape(*lead, padded // BLOCK, BLOCK)
    out = (blocks.astype(jnp.float32) * enc["scale"]).reshape(*lead, padded)
    return out[..., :last]


def _moment_init(p, dtype: str):
    if dtype == "int8":
        return _q8_encode(jnp.zeros_like(p, jnp.float32))
    return jnp.zeros_like(p, jnp.float32)


def _moment_read(m, dtype: str, like=None, *, sqrt_domain: bool = False):
    if dtype == "int8":
        x = _q8_decode(m, like.shape, like.size)
        return jnp.square(x) if sqrt_domain else x
    return m


def _moment_write(x, dtype: str, *, sqrt_domain: bool = False):
    """sqrt_domain: the SECOND moment must be stored as sqrt(v) — linear
    int8 quantization of v crushes small entries within a block to zero
    and 1/sqrt(v) explodes (measured: loss 6.7 -> diverged).  In the
    sqrt domain the same 127 levels track the f32 trajectory exactly
    (EXPERIMENTS.md §Perf, Hillclimb 3 coda)."""
    if dtype == "int8":
        return _q8_encode(jnp.sqrt(x) if sqrt_domain else x)
    return x


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def init_opt_state(cfg: OptimizerConfig, params: Params):
    is_q8_leaf = lambda x: isinstance(x, dict) and "q" in x
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: _moment_init(p, cfg.moments_dtype), params),
        "v": jax.tree.map(lambda p: _moment_init(p, cfg.moments_dtype), params),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: OptimizerConfig, grads: Params, opt_state, params: Params):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    dt = cfg.moments_dtype
    is_q8 = lambda x: isinstance(x, dict) and "q" in x

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _moment_read(m, dt, like=g)
        v_f = _moment_read(v, dt, like=g, sqrt_domain=True)
        m_f = b1 * m_f + (1.0 - b1) * g
        v_f = b2 * v_f + (1.0 - b2) * jnp.square(g)
        mhat = m_f / bc1
        vhat = v_f / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (delta + cfg.weight_decay * p32)
        return (new_p.astype(p.dtype), _moment_write(m_f, dt),
                _moment_write(v_f, dt, sqrt_domain=True))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


def sgd_update(params: Params, grads: Params, lr: float):
    """Plain SGD (used by federated local steps)."""
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
