"""Training loop: train_step factory with grad accumulation + remat.

``make_train_step`` returns a pure function suitable for ``jax.jit``
(and for ``.lower().compile()`` in the multi-pod dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training import optimizer as opt

Params = Any


@dataclass(frozen=True)
class TrainConfig:
    optimizer: opt.OptimizerConfig = opt.OptimizerConfig()
    microbatches: int = 1          # grad accumulation steps
    remat: Optional[str] = "nothing_saveable"  # jax.checkpoint policy name
    use_flash: bool = False
    use_kernel: bool = False
    accum_dtype: str = "float32"   # grad-accumulator dtype (bf16 halves
                                   # the accumulator HBM at 1T scale)


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key):
    params = M.init_params(cfg, key)
    return {"params": params,
            "opt": opt.init_opt_state(tcfg.optimizer, params)}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """(state, batch) -> (state, metrics).  batch leaves: (B, ...)."""

    def loss(params, batch):
        return M.loss_fn(cfg, params, batch, use_flash=tcfg.use_flash,
                         use_kernel=tcfg.use_kernel, remat=tcfg.remat)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def single(params, batch):
        (l, metrics), grads = grad_fn(params, batch)
        return l, metrics, grads

    def accumulated(params, batch):
        n = tcfg.microbatches
        micro = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

        adt = jnp.dtype(tcfg.accum_dtype)

        def body(carry, mb):
            acc, lsum = carry
            (l, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(adt), acc, grads)
            return (acc, lsum + l), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
        (grads, lsum), metrics = lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g: g / n, grads)
        metrics = jax.tree.map(lambda x: x[-1], metrics)
        return lsum / n, metrics, grads

    def train_step(state, batch):
        fn = single if tcfg.microbatches <= 1 else accumulated
        l, metrics, grads = fn(state["params"], batch)
        new_params, new_opt, opt_metrics = opt.adamw_update(
            tcfg.optimizer, grads, state["opt"], state["params"])
        metrics = dict(metrics, loss=l, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, data_iter, num_steps: int,
               *, key=None, state=None, log_every: int = 10,
               callback=None):
    """Eager CPU-scale loop used by examples/tests (single device)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if state is None:
        state = init_train_state(cfg, tcfg, key)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=0)
    history = []
    for i in range(num_steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            if callback:
                callback(i, m)
    return state, history
