"""Collaborative learning at the consumer edge: FedAvg + DP + SecAgg.

Implements the paper's Privacy pillar (Table 1) end-to-end:

* **FedAvg** rounds over heterogeneous edge clients (the orchestrator
  schedules which devices participate — see ``core.orchestrator``).
* **Differential privacy** (McMahan et al., ICLR'18): per-client update
  clipping + Gaussian noise on the aggregate.
* **Secure aggregation** (Bonawitz et al.): pairwise PRG masks derived
  from shared seeds; masks cancel exactly in the sum, so the server only
  ever sees the aggregate.  (Key agreement itself is out of scope — the
  seed matrix stands in for the DH exchange.)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training import optimizer as opt

Params = Any


@dataclass(frozen=True)
class FedConfig:
    num_clients: int = 8
    clients_per_round: int = 4
    local_steps: int = 4
    local_lr: float = 0.05
    # differential privacy (0 disables)
    dp_clip: float = 0.0
    dp_noise_multiplier: float = 0.0
    # secure aggregation
    secure_aggregation: bool = False
    seed: int = 0


# ---------------------------------------------------------------------------
# local training
# ---------------------------------------------------------------------------

def local_update(cfg: ModelConfig, fcfg: FedConfig, params: Params,
                 batches: Sequence[dict]) -> Params:
    """Run local SGD steps; return the DELTA (new - old)."""
    p = params

    @jax.jit
    def step(p, batch):
        grads = jax.grad(lambda q: M.loss_fn(cfg, q, batch)[0])(p)
        return opt.sgd_update(p, grads, fcfg.local_lr)

    for b in batches[: fcfg.local_steps]:
        p = step(p, b)
    return jax.tree.map(lambda a, b: a.astype(jnp.float32)
                        - b.astype(jnp.float32), p, params)


# ---------------------------------------------------------------------------
# privacy mechanisms
# ---------------------------------------------------------------------------

def clip_update(delta: Params, clip: float) -> Params:
    norm = opt.global_norm(delta)
    factor = jnp.minimum(1.0, clip / (norm + 1e-12))
    return jax.tree.map(lambda x: x * factor, delta)


def add_gaussian_noise(tree: Params, sigma: float, key) -> Params:
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noisy = [x + sigma * jax.random.normal(k, x.shape, jnp.float32)
             for x, k in zip(leaves, keys)]
    return treedef.unflatten(noisy)


def _pair_mask(tree: Params, seed: int) -> Params:
    leaves, treedef = jax.tree.flatten(tree)
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten(
        [jax.random.normal(k, x.shape, jnp.float32)
         for x, k in zip(leaves, keys)])


def secagg_mask(tree: Params, client: int, others: Sequence[int],
                round_seed: int) -> Params:
    """Sum of pairwise masks for ``client``: +mask(i,j) if i<j else -."""
    masked = tree
    for other in others:
        if other == client:
            continue
        i, j = min(client, other), max(client, other)
        seed = (round_seed * 1_000_003 + i * 1009 + j) % (2 ** 31)
        mask = _pair_mask(tree, seed)
        sign = 1.0 if client == i else -1.0
        masked = jax.tree.map(lambda a, m: a + sign * m, masked, mask)
    return masked


# ---------------------------------------------------------------------------
# federated round
# ---------------------------------------------------------------------------

def fed_round(cfg: ModelConfig, fcfg: FedConfig, params: Params,
              client_batches: dict[int, Sequence[dict]], round_idx: int,
              *, key=None) -> tuple[Params, dict]:
    """One FedAvg round over the given clients' local data."""
    key = key if key is not None else jax.random.PRNGKey(fcfg.seed + round_idx)
    clients = sorted(client_batches)
    deltas = {}
    for c in clients:
        d = local_update(cfg, fcfg, params, client_batches[c])
        if fcfg.dp_clip:
            d = clip_update(d, fcfg.dp_clip)
        if fcfg.secure_aggregation:
            d = secagg_mask(d, c, clients, round_seed=fcfg.seed + round_idx)
        deltas[c] = d

    # server only ever computes the SUM (SecAgg masks cancel here)
    total = jax.tree.map(lambda *xs: sum(xs), *deltas.values())
    avg = jax.tree.map(lambda x: x / len(clients), total)

    if fcfg.dp_clip and fcfg.dp_noise_multiplier:
        sigma = fcfg.dp_noise_multiplier * fcfg.dp_clip / len(clients)
        avg = add_gaussian_noise(avg, sigma, key)

    new_params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype), params, avg)
    update_norm = float(opt.global_norm(avg))
    return new_params, {"clients": clients, "update_norm": update_norm}
