"""Always-on serving frontend over the step-driven engine.

``AsyncServingFrontend`` wraps ``EdgeServingEngine`` in an asyncio
event loop with no drain assumption: requests arrive and are cancelled
mid-flight, tokens stream to per-request callbacks / async iterators
as each engine step retires them, and a graceful shutdown flushes the
prefix-persist store via ``engine.close()``.

Threading model: the engine is single-threaded.  All engine calls
(``submit`` / ``cancel`` / ``step`` / ``close``) happen from the one
background ``_run`` task; the public API only posts intents to an
inbox and wakes the loop, so callers never race a step that is
executing in the default executor.  ``step()`` itself runs via
``run_in_executor`` so the event loop stays responsive to arrivals
during a jitted wave.

CLI:

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      --requests 8 --max-new 16 --policy edf --mode async

``--mode async`` (default) staggers arrivals over the run and streams
tokens as they retire; ``--mode drain`` keeps the legacy
submit-all-then-drain loop.  Reports tokens/sec, TTFT and inter-token
latency percentiles, and SLO goodput.
"""
from __future__ import annotations

import argparse
import asyncio
import json
from typing import Callable, Optional

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config, get_config
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig
from repro.serving.telemetry import FRONTEND_TID, default_clock


class StreamHandle:
    """Per-request streaming view handed back by ``submit``.

    Tokens arrive on an asyncio queue as the engine retires them
    (``None`` sentinel terminates the stream); ``done`` resolves with
    the finished ``Request`` (``req.cancelled`` distinguishes a
    mid-flight cancel from natural completion).
    """

    def __init__(self, req: Request, clock: Callable[[], float] = None):
        self.req = req
        self.uid = req.uid
        self.delivered = 0
        self.tokens: asyncio.Queue = asyncio.Queue()
        self.done: asyncio.Future = (
            asyncio.get_event_loop().create_future())
        self._clock = clock if clock is not None else default_clock
        self.t_submit = self._clock()
        self.t_tokens: list[float] = []     # arrival time of each token

    def __aiter__(self):
        return self._gen()

    async def _gen(self):
        while True:
            tok = await self.tokens.get()
            if tok is None:
                return
            yield tok


class AsyncServingFrontend:
    """Always-on asyncio frontend: admit, stream, cancel, shut down.

    The background task loops ``engine.step()`` while work exists and
    parks on an event when idle, so an idle frontend burns no cycles
    but wakes instantly on the next arrival.  Per-token delivery works
    by diffing ``req.generated`` after each step — the engine stays
    oblivious to the frontend.
    """

    def __init__(self, engine: EdgeServingEngine,
                 trace_path: Optional[str] = None):
        self.engine = engine
        self.trace_path = trace_path
        # share the engine's trace clock so frontend stamps and engine
        # spans land on one timeline (monotonic, never the wall clock)
        self._clock = (engine.tracer.clock if engine.tracer is not None
                       else default_clock)
        self._inbox: list[Request] = []
        self._cancels: list[tuple[int, asyncio.Future, float]] = []
        self._handles: dict[int, StreamHandle] = {}
        self._callbacks: dict[int, Callable] = {}
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._closing = False
        self.ttft_ms: list[float] = []      # first-token latency/request
        self.itl_ms: list[float] = []       # every inter-token gap
        self.cancel_ms: list[float] = []    # cancel() call -> applied
        self.steps = 0
        m = engine.metrics
        m.gauge("frontend.steps", lambda: self.steps)
        m.gauge("frontend.streams", lambda: len(self._handles))
        m.gauge("frontend.inbox_depth", lambda: len(self._inbox))
        m.gauge("frontend.pending_cancels", lambda: len(self._cancels))

    def metrics(self) -> dict:
        """Deterministic snapshot of the WHOLE registry — engine,
        subsystems, and the frontend gauges above."""
        return self.engine.metrics.collect()

    # -- public API (call from coroutines on the running loop) --------
    async def start(self) -> None:
        self._wake = asyncio.Event()
        self._task = asyncio.create_task(self._run())

    def submit(self, req: Request,
               on_token: Optional[Callable[[Request, int], None]] = None,
               ) -> StreamHandle:
        """Enqueue a request; returns a handle streaming its tokens."""
        if self._closing:
            raise RuntimeError("frontend is shutting down")
        h = StreamHandle(req, clock=self._clock)
        self._handles[req.uid] = h
        if on_token is not None:
            self._callbacks[req.uid] = on_token
        self._inbox.append(req)
        self._wake.set()
        return h

    def cancel(self, uid: int) -> asyncio.Future:
        """Request mid-flight cancellation; the future resolves
        True/False once the engine processed it (between steps)."""
        fut = asyncio.get_event_loop().create_future()
        self._cancels.append((uid, fut, self._clock()))
        self._wake.set()
        return fut

    async def shutdown(self, drain: bool = True) -> dict:
        """Stop the loop and flush the prefix-persist store.

        ``drain=True`` finishes in-flight and queued work first;
        ``drain=False`` cancels everything outstanding.
        """
        if not drain:
            for uid in list(self._handles):
                self.cancel(uid)
        self._closing = True
        self._wake.set()
        if self._task is not None:
            await self._task
        out = self.engine.close()           # persists hot chains
        if self.trace_path and self.engine.tracer is not None:
            out["trace"] = dict(
                self.engine.dump_chrome_trace(self.trace_path),
                path=self.trace_path)
        return out

    def slo_stats(self, ttft_slo_ms: float = 1e9,
                  itl_slo_ms: float = 1e9) -> dict:
        """TTFT/ITL percentiles plus goodput under the given SLO."""
        def pct(xs, q):
            if not xs:
                return 0.0
            s = sorted(xs)
            return s[min(len(s) - 1, int(q * len(s)))]
        return {
            "ttft_p50_ms": round(pct(self.ttft_ms, 0.50), 2),
            "ttft_p99_ms": round(pct(self.ttft_ms, 0.99), 2),
            "itl_p50_ms": round(pct(self.itl_ms, 0.50), 2),
            "itl_p99_ms": round(pct(self.itl_ms, 0.99), 2),
            "goodput_ttft": round(
                sum(1 for t in self.ttft_ms if t <= ttft_slo_ms)
                / max(1, len(self.ttft_ms)), 3),
            "goodput_itl": round(
                sum(1 for t in self.itl_ms if t <= itl_slo_ms)
                / max(1, len(self.itl_ms)), 3),
        }

    # -- internals ----------------------------------------------------
    def _drain_control(self) -> None:
        """Apply queued submits/cancels on the loop thread, between
        steps — the only place besides ``step`` that touches the
        engine."""
        eng = self.engine
        tr = eng.tracer
        inbox, self._inbox = self._inbox, []
        for req in inbox:
            h = self._handles[req.uid]
            h.t_submit = self._clock()
            eng.submit(req)
        cancels, self._cancels = self._cancels, []
        for uid, fut, t0 in cancels:
            ok = eng.cancel(uid)
            if ok:
                lat_ms = (self._clock() - t0) * 1e3
                self.cancel_ms.append(lat_ms)
                if tr is not None:
                    tr.instant("cancel_applied", tid=FRONTEND_TID,
                               uid=uid, latency_ms=lat_ms)
            if not fut.done():
                fut.set_result(ok)
            if ok:
                self._resolve(uid)
        if tr is not None and (inbox or cancels):
            tr.counter("frontend_queues", tid=FRONTEND_TID,
                       streams=len(self._handles),
                       engine_queue=len(eng.queue))

    def _deliver(self) -> None:
        """Diff ``req.generated`` against what each handle has seen and
        stream the delta; resolve handles whose request finished."""
        now = self._clock()
        for uid in list(self._handles):
            h = self._handles[uid]
            req = h.req
            n = len(req.generated)
            while h.delivered < n:
                tok = int(req.generated[h.delivered])
                h.delivered += 1
                if h.t_tokens:
                    self.itl_ms.append((now - h.t_tokens[-1]) * 1e3)
                else:
                    self.ttft_ms.append((now - h.t_submit) * 1e3)
                h.t_tokens.append(now)
                h.tokens.put_nowait(tok)
                cb = self._callbacks.get(uid)
                if cb is not None:
                    cb(req, tok)
            if req.done:
                self._resolve(uid)

    def _resolve(self, uid: int) -> None:
        h = self._handles.pop(uid, None)
        self._callbacks.pop(uid, None)
        if h is None:
            return
        h.tokens.put_nowait(None)
        if not h.done.done():
            h.done.set_result(h.req)

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        eng = self.engine
        while True:
            self._drain_control()
            busy = bool(eng.queue) or bool(eng.active.any())
            if busy:
                await loop.run_in_executor(None, eng.step)
                self.steps += 1
                self._deliver()
                continue
            if self._inbox or self._cancels:
                continue                    # new intents — apply now
            if self._closing:
                return
            self._wake.clear()
            if self._inbox or self._cancels or self._closing:
                continue                    # landed before the clear
            await self._wake.wait()


# ---------------------------------------------------------------- CLI
def _build_engine(args):
    cfg = (get_smoke_config(args.arch) if args.scale == "smoke"
           else get_config(args.arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if args.params:
        from repro.training import checkpoint as ckpt
        params = ckpt.restore(args.params, params)
    scfg = ServeConfig(max_slots=args.slots, max_len=args.max_len,
                       temperature=args.temperature, top_k=args.top_k,
                       policy=args.policy, spec_decode=args.spec,
                       draft_arch=args.draft if args.spec else None,
                       spec_gamma=args.gamma,
                       chunked_prefill=args.chunked,
                       prefix_persist_path=args.persist,
                       trace=bool(args.trace))
    return cfg, EdgeServingEngine(cfg, params, scfg)


def _make_requests(cfg, args) -> list:
    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.requests):
        n = int(rng.integers(args.min_prompt, args.max_prompt + 1))
        extras = {}
        if cfg.family == "vlm":
            extras["image_embeds"] = rng.normal(
                0, 0.1, (cfg.num_image_tokens, cfg.image_embed_dim)
            ).astype(np.float32)
        if cfg.family == "encdec":
            extras["audio_embeds"] = rng.normal(
                0, 0.1, (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, n, dtype=np.int32),
            max_new_tokens=args.max_new,
            priority=uid % 3,
            deadline=float(uid) if args.policy == "edf" else None,
            extras=extras))
    return reqs


async def _serve_async(eng, reqs, args) -> dict:
    """Open-loop style demo: staggered arrivals into a live frontend."""
    fe = AsyncServingFrontend(eng, trace_path=args.trace)
    await fe.start()
    handles = []
    gap = args.arrival_gap_ms / 1e3
    for req in reqs:
        handles.append(fe.submit(req))
        if gap:
            await asyncio.sleep(gap)
    done = [await h.done for h in handles]
    out = dict(fe.slo_stats())
    out.update(await fe.shutdown())
    out["requests"] = len(done)
    out["tokens"] = sum(len(r.generated) for r in done)
    out["decode_steps"] = fe.steps
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-1b")
    ap.add_argument("--scale", choices=("smoke", "full"), default="smoke")
    ap.add_argument("--mode", choices=("async", "drain"), default="async",
                    help="async: always-on frontend with staggered "
                         "arrivals and streaming; drain: legacy "
                         "submit-all-then-drain loop")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="0 disables top-k filtering")
    ap.add_argument("--policy", choices=("fifo", "priority", "edf"),
                    default="priority",
                    help="QoE admission ordering (core.scheduler)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding (serving.spec_decode)")
    ap.add_argument("--draft", default="self",
                    help="draft arch for --spec: a registry id, or "
                         "'self' for the early-exit self-draft")
    ap.add_argument("--gamma", type=int, default=4,
                    help="speculation width (proposals per round + 1); "
                         "also the multi-token catch-up chunk")
    ap.add_argument("--chunked", action="store_true",
                    help="chunked prefill: admit prompts as wave spans "
                         "interleaved with decode (no blocking prefill)")
    ap.add_argument("--arrival-gap-ms", type=float, default=5.0,
                    help="async mode: gap between request arrivals")
    ap.add_argument("--persist", metavar="PATH", default=None,
                    help="prefix-store path: rehydrate the radix prefix "
                         "cache from PATH at startup (warm TTFT after a "
                         "hub restart) and save the hot chains back on "
                         "exit; a corrupt or mismatched-config store is "
                         "rejected cleanly (cold start).  Only engages "
                         "on prefix-sharable archs (see "
                         "scripts/diagnose.py --cache)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable wave/request tracing and dump a "
                         "Perfetto/chrome://tracing JSON to PATH at "
                         "shutdown (summarize with scripts/diagnose.py "
                         "--trace PATH)")
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--params", default=None,
                    help="checkpoint from launch.train (else random init)")
    args = ap.parse_args()

    cfg, eng = _build_engine(args)
    reqs = _make_requests(cfg, args)
    t0 = default_clock()

    if args.mode == "async":
        out = asyncio.run(_serve_async(eng, reqs, args))
        dt = default_clock() - t0
        out["elapsed_s"] = round(dt, 2)
        out["tok_per_s"] = round(out["tokens"] / dt, 1)
        out["policy"] = args.policy
        done = eng.completed
    else:
        t_submit, t_first = {}, {}
        for req in reqs:
            eng.submit(req)
            t_submit[req.uid] = default_clock()
        while eng.queue or eng.active.any():
            eng.step()
            now = default_clock()
            for r in reqs:
                if r.uid not in t_first and r.generated:
                    t_first[r.uid] = now
        done = eng.completed
        dt = default_clock() - t0
        toks = sum(len(r.generated) for r in done)
        ttft = sorted((t_first[u] - t_submit[u]) * 1e3 for u in t_first)
        out = {
            "requests": len(done), "decode_steps": eng.steps,
            "tokens": toks, "elapsed_s": round(dt, 2),
            "tok_per_s": round(toks / dt, 1),
            "ttft_p50_ms": round(ttft[len(ttft) // 2], 1),
            "ttft_p99_ms": round(ttft[min(len(ttft) - 1,
                                          int(0.99 * len(ttft)))], 1),
            "policy": args.policy,
        }
        if args.persist:
            out.update(eng.close())     # save the warm chains back
        if args.trace:
            out["trace"] = dict(eng.dump_chrome_trace(args.trace),
                                path=args.trace)

    st = eng.stats()
    if args.spec:
        out.update({
            "spec_active": st["spec_active"],
            "spec_accept_rate": round(st["spec_acceptance"], 3),
            "spec_tokens_per_step": round(st["spec_tokens_per_round"], 3),
        })
    if args.chunked:
        out.update({"mixed_waves": st["mixed_waves"],
                    "wave_admitted": st["wave_admitted"]})
    if args.persist:
        out.update({
            "persist_loaded_chains": st.get("persist_loaded_chains", 0),
            "persist_loaded_blocks": st.get("persist_loaded_blocks", 0),
            "persist_rejected": st.get("persist_rejected", ""),
            "prefix_hits": st.get("prefix_hits", 0),
            "prefix_hit_tokens": st.get("prefix_hit_tokens", 0),
        })
    print(json.dumps(out))
    for r in done[:3]:
        print(f"  req {r.uid}: {list(map(int, r.generated[:10]))}...")


if __name__ == "__main__":
    main()
