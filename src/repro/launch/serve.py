"""Serving launcher: EdgeAI-Hub engine with batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      --requests 8 --max-new 16 --policy edf --top-k 4

Traffic is a mixed prompt-length workload (some prompts exceed the
largest prefill bucket to exercise chunked admission); per-request
sampling params and QoE metadata (priority/deadline) ride on each
Request.  Reports tokens/sec and TTFT percentiles.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config, get_config
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-1b")
    ap.add_argument("--scale", choices=("smoke", "full"), default="smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="0 disables top-k filtering")
    ap.add_argument("--policy", choices=("fifo", "priority", "edf"),
                    default="priority",
                    help="QoE admission ordering (core.scheduler)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding (serving.spec_decode)")
    ap.add_argument("--draft", default="self",
                    help="draft arch for --spec: a registry id, or "
                         "'self' for the early-exit self-draft")
    ap.add_argument("--gamma", type=int, default=4,
                    help="speculation width (proposals per round + 1); "
                         "also the multi-token catch-up chunk")
    ap.add_argument("--persist", metavar="PATH", default=None,
                    help="prefix-store path: rehydrate the radix prefix "
                         "cache from PATH at startup (warm TTFT after a "
                         "hub restart) and save the hot chains back on "
                         "exit; a corrupt or mismatched-config store is "
                         "rejected cleanly (cold start).  Only engages "
                         "on prefix-sharable archs (see "
                         "scripts/diagnose.py --cache)")
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--params", default=None,
                    help="checkpoint from launch.train (else random init)")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.scale == "smoke"
           else get_config(args.arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if args.params:
        from repro.training import checkpoint as ckpt
        params = ckpt.restore(args.params, params)

    scfg = ServeConfig(max_slots=args.slots, max_len=args.max_len,
                       temperature=args.temperature, top_k=args.top_k,
                       policy=args.policy, spec_decode=args.spec,
                       draft_arch=args.draft if args.spec else None,
                       spec_gamma=args.gamma,
                       prefix_persist_path=args.persist)
    eng = EdgeServingEngine(cfg, params, scfg)

    rng = np.random.default_rng(0)
    t0 = time.time()
    t_submit, t_first = {}, {}
    reqs = []
    for uid in range(args.requests):
        n = int(rng.integers(args.min_prompt, args.max_prompt + 1))
        extras = {}
        if cfg.family == "vlm":
            extras["image_embeds"] = rng.normal(
                0, 0.1, (cfg.num_image_tokens, cfg.image_embed_dim)
            ).astype(np.float32)
        if cfg.family == "encdec":
            extras["audio_embeds"] = rng.normal(
                0, 0.1, (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        req = Request(uid=uid,
                      prompt=rng.integers(0, cfg.vocab_size, n,
                                          dtype=np.int32),
                      max_new_tokens=args.max_new,
                      priority=uid % 3,
                      deadline=float(uid) if args.policy == "edf" else None,
                      extras=extras)
        reqs.append(req)
        eng.submit(req)
        t_submit[uid] = time.time()

    while eng.queue or eng.active.any():
        eng.step()
        now = time.time()
        for r in reqs:
            if r.uid not in t_first and r.generated:
                t_first[r.uid] = now
    done = eng.completed
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    ttft = sorted((t_first[u] - t_submit[u]) * 1e3 for u in t_first)
    out = {
        "requests": len(done), "decode_steps": eng.steps,
        "tokens": toks, "elapsed_s": round(dt, 2),
        "tok_per_s": round(toks / dt, 1),
        "ttft_p50_ms": round(ttft[len(ttft) // 2], 1),
        "ttft_p99_ms": round(ttft[min(len(ttft) - 1,
                                      int(0.99 * len(ttft)))], 1),
        "policy": args.policy,
    }
    if args.spec:
        st = eng.stats()
        out.update({
            "spec_active": st["spec_active"],
            "spec_accept_rate": round(st["spec_acceptance"], 3),
            "spec_tokens_per_step": round(st["spec_tokens_per_round"], 3),
        })
    if args.persist:
        st = eng.stats()
        out.update({
            "persist_loaded_chains": st.get("persist_loaded_chains", 0),
            "persist_loaded_blocks": st.get("persist_loaded_blocks", 0),
            "persist_rejected": st.get("persist_rejected", ""),
            "prefix_hits": st.get("prefix_hits", 0),
            "prefix_hit_tokens": st.get("prefix_hit_tokens", 0),
        })
        out.update(eng.close())         # save the warm chains back
    print(json.dumps(out))
    for r in done[:3]:
        print(f"  req {r.uid}: {list(map(int, r.generated[:10]))}...")


if __name__ == "__main__":
    main()
