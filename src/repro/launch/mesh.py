"""Production mesh construction (dry-run target: TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init — see dryrun.py which
sets XLA_FLAGS before any import).
"""
from __future__ import annotations

import jax

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh():
    """Whatever this host offers (tests/examples: 1 CPU device)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


def mesh_devices(mesh) -> int:
    return mesh.devices.size
