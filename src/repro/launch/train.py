"""Distributed training launcher.

Runs real steps on whatever mesh the host offers (CPU: 1 device; a TPU
slice: the production mesh).  The same ``build_train`` artifact the
dry-run compiles is executed here with live data from the pipeline —
config system, sharding rules and step function are shared, so a
passing dry-run IS the deploy config.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
      --steps 50 --batch 8 --seq 128 --scale smoke
"""
from __future__ import annotations

import argparse
import json
from repro.serving.telemetry import default_clock

import jax

from repro.configs import INPUT_SHAPES, ARCH_IDS, InputShape, get_config, \
    get_smoke_config
from repro.data import DataConfig, data_iterator
from repro.launch import specs as sp
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training import trainer as tr


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-1b")
    ap.add_argument("--scale", choices=("smoke", "full"), default="smoke",
                    help="smoke = reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moments", choices=("float32", "int8"),
                    default="float32")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.scale == "smoke"
           else get_config(args.arch))
    shape = InputShape("cli", args.seq, args.batch, "train")
    cfg = M.specialize(cfg, shape)
    mesh = make_local_mesh()
    tcfg = tr.TrainConfig(
        optimizer=opt.OptimizerConfig(
            learning_rate=args.lr, warmup_steps=max(args.steps // 10, 1),
            total_steps=args.steps, moments_dtype=args.moments),
        microbatches=args.microbatches)

    built = sp.build_train(cfg, shape, mesh, tcfg)
    state = tr.init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    it = data_iterator(cfg, shape, DataConfig(branching=4))

    t0 = default_clock()
    for step in range(args.steps):
        batch = next(it)
        state, metrics = built.fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: round(float(v), 4) for k, v in metrics.items()}
            print(json.dumps({"step": step,
                              "elapsed_s": round(default_clock() - t0, 1), **m}))
    if args.checkpoint:
        ckpt.save(args.checkpoint, state["params"],
                  {"arch": args.arch, "steps": args.steps})
        print(f"saved params -> {args.checkpoint}")


if __name__ == "__main__":
    main()
