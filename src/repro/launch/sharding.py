"""Sharding rules: params / batches / caches -> PartitionSpecs.

Rule engine over leaf *names* with dims-addressed-from-the-right (so the
same rule covers scan-stacked and unstacked params).  Every candidate
axis assignment is divisibility-checked against the mesh — non-divisible
dims fall back down the candidate list, ending at replication.  This is
what lets ONE rule set cover all 10 architectures on the (16,16) and
(2,16,16) production meshes.

``fsdp=True`` additionally shards a second dim of every large tensor
over the data axis (ZeRO-3-style), the lever that fits the 1T-param MoE
(EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from repro.compat import Mesh, NamedSharding
from repro.compat import PartitionSpec as P
from repro.configs.base import InputShape, ModelConfig


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axsize(mesh: Mesh, ax) -> int:
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return mesh.shape[ax]


def _assign(shape, mesh, candidates) -> P:
    """candidates: list of (dim_from_right, axis). First divisible wins
    per axis; one dim gets at most one axis."""
    spec = [None] * len(shape)
    used_dims = set()
    used_axes = set()
    for dim_r, ax in candidates:
        dim = len(shape) + dim_r if dim_r < 0 else dim_r
        if dim < 0 or dim >= len(shape) or dim in used_dims:
            continue
        key = ax if isinstance(ax, tuple) else (ax,)
        if any(a in used_axes for a in key):
            continue
        if shape[dim] % _axsize(mesh, ax) == 0 and shape[dim] > 0:
            spec[dim] = ax
            used_dims.add(dim)
            used_axes.update(key)
    return P(*spec)


# rule table: leaf name -> (primary candidates, fsdp extra candidates)
# dims are from-the-right so scan-stacking prefixes don't matter.
# CAREFUL: expert tensors (E, d, f) share leaf names with dense MLPs
# (d, f) — disambiguated by rank in the rule fn (a -3 rule applied to a
# scan-stacked dense (L, d, f) would shard the LAYER dim, which makes
# XLA all-gather the whole stack per step).
_PARAM_RULES = {
    # attention
    "wq": ([(-2, "model"), (-3, "model")], [(-3, "data")]),
    "wk": ([(-2, "model"), (-1, "model"), (-3, "model")], [(-3, "data")]),
    "wv": ([(-2, "model"), (-1, "model"), (-3, "model")], [(-3, "data")]),
    "wo": ([(-3, "model"), (-1, "model")], [(-1, "data")]),
    # dense MLPs (2D unstacked)
    "w_gate": ([(-1, "model")], [(-2, "data")]),
    "w_up": ([(-1, "model")], [(-2, "data")]),
    "w_down": ([(-2, "model")], [(-1, "data")]),
    "router": ([(-1, "model")], []),
    # whisper mlp
    "w_in": ([(-1, "model")], [(-2, "data")]),
    "w_out": ([(-2, "model")], [(-1, "data")]),
    # ssm
    "in_proj": ([(-2, "model")], [(-1, "data")]),
    "out_proj": ([(-2, "model")], [(-1, "data")]),
    # embeddings
    "table": ([(-2, "model"), (-1, "model")], [(-1, "data"), (-2, "data")]),
    "w": ([(-1, "model"), (-2, "model")], [(-2, "data")]),  # unembed
    "w1": ([(-1, "model")], []),                            # vlm projector
    "w2": ([(-1, "model")], []),
    "pos_table": ([], []),
}

# routed-expert tensors: (layers?, E, d, f) — expert-parallel over model,
# fsdp shards the ffn dim over data (the 1T-MoE memory lever)
_EXPERT_RULES = {
    "w_gate": ([(-3, "model"), (-1, "model")], [(-1, "data")]),
    "w_up": ([(-3, "model"), (-1, "model")], [(-1, "data")]),
    "w_down": ([(-3, "model"), (-2, "model")], [(-2, "data")]),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def param_pspecs(cfg: ModelConfig, mesh: Mesh, params_shape: Any,
                 *, fsdp: bool = False, smart: bool = False) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (shapes pytree).

    ``smart=True`` enables the §Perf beyond-baseline rules: attention
    projections are kept OFF the model axis when the head counts don't
    divide it (indivisible-head sharding leaves q/k sharded on head_dim,
    which makes XLA all-reduce an S x S score tile per attention block —
    the phi3 prefill pathology).  FSDP then carries the memory.
    """
    msize = mesh.shape["model"]
    heads_div = cfg.num_heads > 0 and cfg.num_heads % msize == 0
    kv_div = cfg.num_kv_heads > 0 and cfg.num_kv_heads % msize == 0
    da = data_axes(mesh)
    dax = da[0] if len(da) == 1 else tuple(da)  # multi-pod: ('pod','data')

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if len(shape) <= 1:
            return P()
        path_keys = [str(getattr(e, "key", "")) for e in path]
        if name in _EXPERT_RULES and len(shape) >= 4 and "moe" in path_keys:
            prim, extra = _EXPERT_RULES[name]
        else:
            prim, extra = _PARAM_RULES.get(name, ([], []))
        if smart:
            if name in ("wq", "wo") and not heads_div:
                prim = []
            if name in ("wk", "wv") and not kv_div:
                prim = []
        extra = [(d, dax if ax == "data" else ax) for d, ax in extra]
        cands = list(prim) + (list(extra) if fsdp else [])
        return _assign(shape, mesh, cands)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_pspecs(param_specs: Any, opt_shape: Any, mesh: Mesh) -> Any:
    """Optimizer state specs: moments MIRROR their parameter's spec.

    int8 moments are shape-preserving (optimizer._q8_encode): q carries
    the parameter spec (last axis kept only while the padded size stays
    divisible); scale drops the last axis (it is per-BLOCK, tiny).
    Mirroring matters: any mismatch forces XLA to reshard the decoded
    f32 moments every step.
    """
    def for_moment(pspec, leaf):
        if isinstance(leaf, dict) and "q" in leaf:
            q_shape = leaf["q"].shape
            entries = list(pspec) + [None] * (len(q_shape) - len(pspec))
            q_spec = []
            for dim, ax in enumerate(entries):
                ok = (ax is not None
                      and q_shape[dim] % _axsize(mesh, ax) == 0)
                q_spec.append(ax if ok else None)
            s_spec = q_spec[:-1] + [None, None]
            return {"q": P(*q_spec), "scale": P(*s_spec)}
        return pspec

    is_q8 = lambda x: isinstance(x, dict) and "q" in x
    return {
        "step": P(),
        "m": jax.tree.map(for_moment, param_specs, opt_shape["m"],
                          is_leaf=lambda x: is_q8(x)),
        "v": jax.tree.map(for_moment, param_specs, opt_shape["v"],
                          is_leaf=lambda x: is_q8(x)),
    }


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_shape: Any) -> Any:
    da = data_axes(mesh)
    ax = da if len(da) == 1 else tuple(da)

    def rule(path, leaf):
        b = leaf.shape[0]
        if b % _axsize(mesh, ax if isinstance(ax, tuple) else ax[0]) == 0:
            first = ax if isinstance(ax, str) else tuple(ax)
            return P(first, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_shape: Any,
                 batch: int, *, smart: bool = False) -> Any:
    """KV/SSM cache specs.  Batch over data when divisible; batch=1
    long-context decode shards the TIME axis over data instead and
    heads/channels over model.

    ``smart=True``: when kv heads don't divide the model axis, shard the
    cache on TIME over model instead of head_dim — head_dim-sharded
    caches force a full per-layer cache all-gather at every decode step
    (the internvl2 decode pathology); time-sharded caches only move an
    (B, H, 1, T) score strip.
    """
    da = data_axes(mesh)
    dax = da[0] if len(da) == 1 else tuple(da)
    d_n = _axsize(mesh, dax)
    m_n = _axsize(mesh, "model")
    batch_ok = batch % d_n == 0
    kv_div = cfg.num_kv_heads > 0 and cfg.num_kv_heads % m_n == 0

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        cands = []
        if name in ("k", "v", "cross_k", "cross_v"):
            # (..., B, T, K, hd)
            kv_c = ([(-2, "model")] if kv_div or not smart
                    else [(-2, "model"), (-3, "model")])
            tail = [(-1, "model")] if not smart else []
            if batch_ok:
                cands = [(-4, dax)] + kv_c + tail
            else:
                cands = [(-3, dax)] + kv_c + tail
        elif name == "slots":
            # (..., B, T)
            cands = [(-2, dax)] if batch_ok else [(-1, dax)]
        elif name == "conv":
            # (..., B, w-1, ch)
            cands = ([(-3, dax), (-1, "model")] if batch_ok
                     else [(-1, "model")])
        elif name == "ssm":
            # (..., B, h, p, n)
            cands = ([(-4, dax), (-3, "model")] if batch_ok
                     else [(-3, "model")])
        return _assign(shape, mesh, cands)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
