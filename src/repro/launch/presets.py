"""Per-architecture runtime presets for the production mesh.

``baseline()`` is the paper-faithful configuration (plain data+tensor
parallel sharding, f32 master weights, no grad accumulation).
``optimized()`` is the beyond-paper configuration found by the §Perf
hillclimb — both are recorded separately in EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class RunPreset:
    microbatches: int = 1
    fsdp: bool = False
    remat: str = "nothing_saveable"
    param_dtype: str = "float32"
    moments_dtype: str = "float32"
    accum_dtype: str = "float32"
    moe_rowwise: bool = False
    smart: bool = False   # §Perf sharding rules (attn-replicate on
                          # indivisible heads, time-sharded kv caches)


_BASE = RunPreset()

# memory-fitting presets per arch (train_4k); found in §Dry-run iteration
_OPTIMIZED = {
    "kimi-k2-1t-a32b": RunPreset(microbatches=16, fsdp=True,
                                 param_dtype="bfloat16",
                                 moments_dtype="int8",
                                 accum_dtype="bfloat16",
                                 moe_rowwise=True, smart=True),
    "granite-moe-1b-a400m": RunPreset(fsdp=True, moe_rowwise=True,
                                      smart=True),
    "internvl2-76b": RunPreset(microbatches=8, fsdp=True,
                               param_dtype="bfloat16",
                               moments_dtype="int8", smart=True),
    "gemma3-27b": RunPreset(microbatches=4, fsdp=True, smart=True),
    "gemma2-9b": RunPreset(microbatches=2, fsdp=True, smart=True),
    "phi3-medium-14b": RunPreset(microbatches=2, fsdp=True,
                                 param_dtype="bfloat16", smart=True),
    "zamba2-7b": RunPreset(microbatches=2, fsdp=True, smart=True),
}
_OPT_DEFAULT = RunPreset(smart=True, fsdp=True, moe_rowwise=True)


def baseline(arch: str) -> RunPreset:
    return _BASE


def optimized(arch: str) -> RunPreset:
    return _OPTIMIZED.get(arch, _OPT_DEFAULT)
