from repro.launch.mesh import make_local_mesh, make_production_mesh

__all__ = ["make_local_mesh", "make_production_mesh"]
