"""Step builders + input_specs for the multi-pod dry-run and launchers.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation).  ``build_train`` /
``build_serve`` / ``build_prefill`` return (jitted_fn, example_args) —
``fn.lower(*args).compile()`` is the dry-run;  feeding real arrays is
the launcher.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch import sharding as sh
from repro.models import model as M
from repro.training import trainer as tr


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for every model input of this (arch, shape)."""
    if shape.kind == "decode":
        B = shape.global_batch
        cache = jax.eval_shape(partial(M.init_cache, cfg, B, shape.seq_len))
        return {
            "cache": cache,
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
    return M.batch_shapes(cfg, shape)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

@dataclass
class Built:
    fn: Any                 # jitted function
    args: tuple             # ShapeDtypeStructs to .lower(*args)
    in_shardings: Any
    out_shardings: Any


def build_train(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                tcfg: Optional[tr.TrainConfig] = None, *,
                fsdp: bool = False, smart: bool = False) -> Built:
    tcfg = tcfg or tr.TrainConfig()
    state_shape = jax.eval_shape(
        partial(tr.init_train_state, cfg, tcfg, jax.random.PRNGKey(0)))
    pspec = sh.param_pspecs(cfg, mesh, state_shape["params"], fsdp=fsdp,
                            smart=smart)
    ospec = sh.opt_pspecs(pspec, state_shape["opt"], mesh)
    state_spec = {"params": pspec, "opt": ospec}

    batch_shape = M.batch_shapes(cfg, shape)
    bspec = sh.batch_pspecs(cfg, mesh, batch_shape)

    step = tr.make_train_step(cfg, tcfg)
    in_sh = (sh.named(mesh, state_spec), sh.named(mesh, bspec))
    out_sh = (sh.named(mesh, state_spec), None)
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0,))
    return Built(fn, (state_shape, batch_shape), in_sh, out_sh)


# ---------------------------------------------------------------------------
# serve: decode + prefill
# ---------------------------------------------------------------------------

def build_serve(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                fsdp: bool = False, smart: bool = False) -> Built:
    """serve_step: ONE new token against a seq_len cache."""
    assert shape.kind == "decode"
    B = shape.global_batch
    specs = input_specs(cfg, shape)
    params_shape = jax.eval_shape(
        partial(M.init_params, cfg, jax.random.PRNGKey(0)))
    pspec = sh.param_pspecs(cfg, mesh, params_shape, smart=smart)
    cspec = sh.cache_pspecs(cfg, mesh, specs["cache"], B, smart=smart)
    da = sh.data_axes(mesh)
    dax = da[0] if len(da) == 1 else tuple(da)
    bdiv = B % sh._axsize(mesh, dax) == 0
    vdiv = cfg.vocab_size % sh._axsize(mesh, "model") == 0
    tok_spec = P(dax, None) if bdiv else P(None, None)
    pos_spec = P(dax) if bdiv else P(None)
    logits_spec = P(dax if bdiv else None, None, "model" if vdiv else None)

    def serve_step(params, cache, tokens, pos):
        return M.decode_step(cfg, params, cache, tokens, pos)

    in_sh = (sh.named(mesh, pspec), sh.named(mesh, cspec),
             NamedSharding(mesh, tok_spec), NamedSharding(mesh, pos_spec))
    out_sh = (NamedSharding(mesh, logits_spec), sh.named(mesh, cspec))
    fn = jax.jit(serve_step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(1,))
    args = (params_shape, specs["cache"], specs["tokens"], specs["pos"])
    return Built(fn, args, in_sh, out_sh)


def build_prefill(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                  fsdp: bool = False, smart: bool = False) -> Built:
    """prefill step: run the whole prompt, emit last logits + full cache."""
    assert shape.kind == "prefill"
    B, S = shape.global_batch, shape.seq_len
    params_shape = jax.eval_shape(
        partial(M.init_params, cfg, jax.random.PRNGKey(0)))
    pspec = sh.param_pspecs(cfg, mesh, params_shape, fsdp=fsdp, smart=smart)
    batch_shape = M.batch_shapes(cfg, shape)
    batch_shape.pop("targets", None)
    bspec = sh.batch_pspecs(cfg, mesh, batch_shape)
    cache_shape = jax.eval_shape(partial(M.init_cache, cfg, B, S))
    cspec = sh.cache_pspecs(cfg, mesh, cache_shape, B, smart=smart)

    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, S)

    da = sh.data_axes(mesh)
    dax = da[0] if len(da) == 1 else tuple(da)
    vdiv = cfg.vocab_size % sh._axsize(mesh, "model") == 0
    logits_spec = P(dax if B % sh._axsize(mesh, dax) == 0 else None, None,
                    "model" if vdiv else None)
    in_sh = (sh.named(mesh, pspec), sh.named(mesh, bspec))
    out_sh = (NamedSharding(mesh, logits_spec), sh.named(mesh, cspec))
    fn = jax.jit(prefill_step, in_shardings=in_sh, out_shardings=out_sh)
    return Built(fn, (params_shape, batch_shape), in_sh, out_sh)


def _maybe_enable_seq_parallel_attn(cfg: ModelConfig, shape: InputShape,
                                    mesh: Mesh) -> None:
    """§Perf: when query heads can't shard over `model`, shard the query
    SEQUENCE over it inside blockwise attention (layers.py knob;
    process-scoped, must stay set through .lower())."""
    from repro.models import layers as L
    msize = mesh.shape["model"]
    heads_div = cfg.num_heads > 0 and cfg.num_heads % msize == 0
    if heads_div or cfg.num_heads == 0 or shape.kind == "decode":
        return
    per = shape.seq_len // msize
    if shape.seq_len % msize or per < L.BLOCKWISE_CHUNK \
            or per % L.BLOCKWISE_CHUNK:
        return
    spec = P(None, "model", None, None, None, None)
    L.SEQ_PARALLEL_ATTN = (msize, NamedSharding(mesh, spec))


def build(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
          tcfg: Optional[tr.TrainConfig] = None, fsdp: bool = False,
          smart: bool = False) -> Built:
    if smart:
        _maybe_enable_seq_parallel_attn(cfg, shape, mesh)
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, tcfg, fsdp=fsdp, smart=smart)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, fsdp=fsdp, smart=smart)
    return build_serve(cfg, shape, mesh, fsdp=fsdp, smart=smart)
