"""Roofline-term extraction from a compiled dry-run artifact.

compute term    = HLO_FLOPs / (chips * peak)    [cost_analysis is
memory term     = HLO_bytes / (chips * HBM_bw)   per-device, so /chip
collective term = collective_bytes / (chips * link_bw)   cancels out]

collective_bytes is NOT in cost_analysis — we parse the post-SPMD HLO
and sum result-buffer sizes of every collective op (shapes in the
partitioned module are already per-device).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Optional

# TPU v5e
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|"
                       r"u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective type (result sizes)."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        result, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(result)
        counts[op] += 1
    out["_counts"] = counts
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    coll_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float           # 6ND (train) / 2ND (serve) over the batch
    useful_ratio: float          # model_flops / (hlo_flops_per_chip * chips)
    bottleneck: str
    arg_bytes_per_chip: float = 0.0
    temp_bytes_per_chip: float = 0.0
    coll_counts: Optional[dict] = None

    def to_dict(self):
        return asdict(self)


def analyze(compiled, *, arch: str, shape_name: str, mesh_name: str,
            chips: int, model_flops: float) -> Roofline:
    """Roofline terms from the compiled module.

    Primary source is the structured HLO walk (launch.hlo_analysis),
    which scales while-loop bodies by trip count — ``cost_analysis()``
    counts scan bodies once and under-reports by ~num_layers.  We take
    the max of both flops numbers defensively.
    """
    from repro.compat import cost_analysis
    from repro.launch.hlo_analysis import analyze_hlo
    hlo = compiled.as_text()
    walked = analyze_hlo(hlo)
    ca = cost_analysis(compiled)
    flops = max(float(ca.get("flops", 0.0)), walked.flops)   # per-device
    nbytes = max(float(ca.get("bytes accessed", 0.0)), walked.bytes)
    counts = {k: int(v) for k, v in walked.coll_counts.items()}
    coll = float(walked.coll_bytes)

    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    ma = compiled.memory_analysis()
    arg_b = float(getattr(ma, "argument_size_in_bytes", 0) or 0)
    tmp_b = float(getattr(ma, "temp_size_in_bytes", 0) or 0)

    total_hlo = flops * chips
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=nbytes,
        coll_bytes_per_chip=coll, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, model_flops=model_flops,
        useful_ratio=(model_flops / total_hlo) if total_hlo else 0.0,
        bottleneck=bottleneck, arg_bytes_per_chip=arg_b,
        temp_bytes_per_chip=tmp_b, coll_counts=counts)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS per step: 6·N_active·tokens (train), 2·N_active·tokens
    (prefill), 2·N_active·batch (decode: one token per sequence)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch
