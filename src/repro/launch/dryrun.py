import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  — the two lines above MUST precede any jax import:
# jax locks the device count at first initialization.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh).

For each combination this proves the distribution config is coherent —
sharding mismatches, non-divisible dims or unsupported collectives fail
here — and extracts the roofline terms (launch.roofline) from the
compiled artifact.  Results stream to stdout and, with --out, to a JSON
lines file that benchmarks/roofline_table.py renders into
EXPERIMENTS.md §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl
"""
import argparse
import json
from repro.serving.telemetry import default_clock
import traceback

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, applicable, get_config
from repro.launch import presets as pz
from repro.launch import roofline as rl
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.training import optimizer as opt
from repro.training import trainer as tr


def run_one(arch: str, shape_name: str, mesh_name: str, *,
            preset: pz.RunPreset, verbose: bool = True) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = M.specialize(get_config(arch), shape)
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    cfg = cfg.replace(param_dtype=preset.param_dtype,
                      moe_rowwise=preset.moe_rowwise)
    tcfg = tr.TrainConfig(
        optimizer=opt.OptimizerConfig(moments_dtype=preset.moments_dtype),
        microbatches=preset.microbatches, remat=preset.remat,
        accum_dtype=preset.accum_dtype)

    t0 = default_clock()
    try:
        built = sp.build(cfg, shape, mesh, tcfg=tcfg, fsdp=preset.fsdp,
                         smart=preset.smart)
        lowered = built.fn.lower(*built.args)
        t_lower = default_clock() - t0
        compiled = lowered.compile()
        t_compile = default_clock() - t0 - t_lower
    except Exception as e:  # a failure HERE is a bug in the system
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}

    chips = mesh.devices.size
    r = rl.analyze(compiled, arch=arch, shape_name=shape_name,
                   mesh_name=mesh_name, chips=chips,
                   model_flops=rl.model_flops_for(cfg, shape))
    rec = {"status": "ok", **r.to_dict(),
           "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
           "preset": preset.__dict__,
           "memory_analysis": str(compiled.memory_analysis())}
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"compute={r.compute_s:.3e}s memory={r.memory_s:.3e}s "
              f"collective={r.collective_s:.3e}s -> {r.bottleneck}-bound; "
              f"args/chip={r.arg_bytes_per_chip/2**30:.2f}GiB "
              f"temp/chip={r.temp_bytes_per_chip/2**30:.2f}GiB "
              f"useful={r.useful_ratio:.2f} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="full grid: every (arch x shape)")
    ap.add_argument("--preset", choices=("baseline", "optimized"),
                    default="baseline")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        combos = [(a, s, m) for a in ARCH_IDS for s in INPUT_SHAPES
                  for m in meshes]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required without --all")
        combos = [(args.arch, args.shape, m) for m in meshes]

    getter = pz.baseline if args.preset == "baseline" else pz.optimized
    n_ok = n_skip = n_err = 0
    for arch, shape, mesh in combos:
        rec = run_one(arch, shape, mesh, preset=getter(arch))
        rec.setdefault("preset_name", args.preset)
        if rec["status"] == "ok":
            n_ok += 1
        elif rec["status"] == "skipped":
            n_skip += 1
            print(f"[{arch} x {shape} x {mesh}] SKIP: {rec['reason']}")
        else:
            n_err += 1
            print(f"[{arch} x {shape} x {mesh}] ERROR: {rec['error']}")
        if args.out:
            with open(args.out, "a") as f:
                rec.pop("trace", None)
                f.write(json.dumps(rec) + "\n")
    print(f"dry-run done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
