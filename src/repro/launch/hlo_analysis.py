"""Structured post-SPMD HLO analysis with while-loop trip-count scaling.

``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE (XLA does
not multiply by trip count), which under-reports FLOPs/bytes by ~the
layer count for scanned models.  This module parses the partitioned HLO
text into computations, builds a per-computation symbol table, and
accumulates costs from ENTRY with every ``while`` body multiplied by its
trip count (recovered from the loop-condition constant).

Costs per op:
  * FLOPs — ``dot`` ops: 2 * prod(batch+free dims) * prod(contracting);
    fusion ops recurse into their called computation.
  * bytes — sum of operand + result buffer sizes for every
    memory-touching op (post-fusion roofline assumption: each top-level
    op streams operands from HBM and writes its result).
  * collective bytes — result sizes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (async -start
    counted, -done skipped).

Shapes in the partitioned module are per-device, so every number this
produces is per-chip.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->\s*(.+?)\s*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\(?[^=]+?\)?)\s+"
    r"([\w\-]+)\((.*)$")
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start", "reduce-scatter-start",
                "all-to-all-start"}
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "while", "conditional", "call", "iota", "reshape",
             "copy-done", "all-reduce-done", "all-gather-done",
             "collective-permute-done", "reduce-scatter-done",
             "all-to-all-done"}


def _shape_elems(shape_str: str):
    """Yield (dtype, dims list) for every array in a (possibly tuple) type."""
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d.strip()]
        yield dt, ds


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, ds in _shape_elems(shape_str):
        total += _DTYPE_BYTES[dt] * math.prod(ds) if ds else _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    shape: str
    opcode: str
    rest: str            # operand list + attributes (raw tail)
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    is_entry: bool
    ops: list = field(default_factory=list)
    table: dict = field(default_factory=dict)   # %name -> shape str


_INSTR_START = re.compile(r"^\s*(ROOT\s+)?%[\w.\-]+\s*=\s")


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _logical_lines(hlo: str):
    """Merge wrapped instruction lines (long tuple types span lines) and
    strip /*...*/ comments (they contain '=' which breaks op parsing)."""
    buf = None
    for line in _COMMENT_RE.sub("", hlo).splitlines():
        stripped = line.strip()
        if _INSTR_START.match(line):
            if buf is not None:
                yield buf
            buf = line
        elif stripped == "}" or (_COMP_HDR.match(stripped)
                                 if "{" in line else False) or \
                stripped.startswith(("HloModule", "ENTRY")):
            if buf is not None:
                yield buf
                buf = None
            yield line
        elif buf is not None:
            buf += " " + stripped
        else:
            yield line
    if buf is not None:
        yield buf


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in _logical_lines(hlo):
        m = _COMP_HDR.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            cur = Computation(m.group(2), bool(m.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        name, shape, opcode, rest = om.groups()
        operands = re.findall(r"%[\w.\-]+", rest.split(")", 1)[0])
        op = Op(name, shape.strip(), opcode, rest, operands)
        cur.ops.append(op)
        cur.table[name] = op.shape
    return comps


def _dot_flops(op: Op, table: dict) -> float:
    lhs_sh = table.get(op.operands[0], "") if op.operands else ""
    lhs_c = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    lhs_b = re.search(r"lhs_batch_dims=\{([0-9,]*)\}", op.rest)
    dims = list(_shape_elems(lhs_sh))
    if not dims:
        return 0.0
    _, lhs_dims = dims[0]
    contract = 1
    if lhs_c:
        for d in lhs_c.group(1).split(","):
            if d.strip():
                contract *= lhs_dims[int(d)]
    out_elems = 0
    for _, ds in _shape_elems(op.shape):
        out_elems += math.prod(ds) if ds else 1
    return 2.0 * out_elems * contract


def _trip_count(cond: Computation) -> int:
    """Scan-style loops compare the induction var against a constant."""
    consts = []
    for o in cond.ops:
        if o.opcode == "constant" and o.shape.startswith("s32[]"):
            m = re.match(r"(\d+)", o.rest)
            if m:
                consts.append(int(m.group(1)))
        # constants may also be inlined in compare(...) operands
        for m in re.finditer(r"s32\[\] constant\((\d+)\)", o.rest):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: dict = field(default_factory=dict)

    def add(self, other: "Costs", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.coll_bytes += other.coll_bytes * scale
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * scale


def _op_called(op: Op) -> dict[str, str]:
    out = {}
    for attr in ("calls", "to_apply", "condition", "body",
                 "true_computation", "false_computation"):
        m = re.search(attr + r"=(%[\w.\-]+)", op.rest)
        if m:
            out[attr] = m.group(1)
    m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
    if m:
        for i, b in enumerate(re.findall(r"%[\w.\-]+", m.group(1))):
            out[f"branch{i}"] = b
    return out


def _sliced_param_bytes(called_comp: Computation) -> dict[int, int]:
    """Parameters of a fusion body that are only dynamic-sliced: charge
    the slice size, not the full buffer (scan weight streaming)."""
    param_idx: dict[str, int] = {}
    for o in called_comp.ops:
        if o.opcode == "parameter":
            m = re.match(r"(\d+)", o.rest)
            if m:
                param_idx[o.name] = int(m.group(1))
    out: dict[int, int] = {}
    uses: dict[str, list] = {}
    for o in called_comp.ops:
        for operand in o.operands:
            if operand in param_idx:
                uses.setdefault(operand, []).append(o)
    for pname, ops in uses.items():
        if ops and all(o.opcode == "dynamic-slice" for o in ops):
            out[param_idx[pname]] = sum(shape_bytes(o.shape) for o in ops)
    return out


def _op_bytes(op: Op, comp: Computation, comps: dict, called: dict) -> int:
    """HBM traffic of one op: result write + operand reads, with
    slice/in-place-update awareness."""
    if op.opcode == "dynamic-slice":
        return 2 * shape_bytes(op.shape)
    if op.opcode == "dynamic-update-slice":
        upd = shape_bytes(comp.table.get(op.operands[1], "")) \
            if len(op.operands) > 1 else 0
        return 2 * upd          # in-place: read+write the update window
    sliced: dict[int, int] = {}
    root_dus_update = None
    if op.opcode == "fusion" and called.get("calls") in comps:
        body = comps[called["calls"]]
        sliced = _sliced_param_bytes(body)
        root = body.ops[-1] if body.ops else None
        if root is not None and root.opcode == "dynamic-update-slice" \
                and len(root.operands) > 1:
            root_dus_update = shape_bytes(
                body.table.get(root.operands[1], ""))

    if root_dus_update is not None:
        b = 2 * root_dus_update    # in-place cache write
    else:
        b = shape_bytes(op.shape)
    for i, o in enumerate(op.operands):
        if i in sliced:
            b += sliced[i]
        elif root_dus_update is not None and i == 0:
            continue               # the aliased full buffer isn't streamed
        else:
            b += shape_bytes(comp.table.get(o, ""))
    return b


def _analyze_comp(name: str, comps: dict, memo: dict) -> Costs:
    if name in memo:
        return memo[name]
    memo[name] = Costs()  # break cycles defensively
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    c = Costs()
    for op in comp.ops:
        called = _op_called(op)
        if op.opcode == "while":
            trip = _trip_count(comps[called["condition"]]) \
                if called.get("condition") in comps else 1
            body = _analyze_comp(called["body"], comps, memo) \
                if called.get("body") else Costs()
            c.add(body, scale=trip)
            continue
        if op.opcode == "conditional":
            branches = [v for k, v in called.items()
                        if k.startswith(("true", "false", "branch"))]
            if branches:
                sub = [_analyze_comp(b, comps, memo) for b in branches]
                # charge the most expensive branch
                c.add(max(sub, key=lambda s: s.flops + s.bytes))
            continue
        if op.opcode == "call" and "to_apply" in called:
            c.add(_analyze_comp(called["to_apply"], comps, memo))
            continue

        if op.opcode == "dot":
            c.flops += _dot_flops(op, comp.table)
        elif op.opcode == "fusion" and "calls" in called:
            inner = _analyze_comp(called["calls"], comps, memo)
            c.flops += inner.flops      # dots inside the fusion body
        elif op.opcode.startswith("convolution"):
            c.flops += 0.0              # none in this framework

        base = op.opcode.replace("-start", "")
        if base in ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute") \
                and not op.opcode.endswith("-done"):
            b = shape_bytes(op.shape)
            c.coll_bytes += b
            c.coll_counts[base] = c.coll_counts.get(base, 0) + 1

        if op.opcode in _FREE_OPS:
            continue
        c.bytes += _op_bytes(op, comp, comps, called)
    memo[name] = c
    return c


def analyze_hlo(hlo_text: str) -> Costs:
    """Per-chip costs of one execution of the module's ENTRY."""
    comps = parse_module(hlo_text)
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return Costs()
    # fusion bodies must not be double counted: they are only reached via
    # fusion ops (handled above), while/call/cond reached explicitly.
    return _analyze_comp(entry, comps, {})


# ---------------------------------------------------------------------------
# per-op breakdown (hillclimb diagnostics)
# ---------------------------------------------------------------------------

def breakdown(hlo_text: str, top: int = 15) -> dict:
    """Top ops by (trip-scaled) bytes / flops / collective bytes, with
    the computation they live in and the loop scale that applies."""
    comps = parse_module(hlo_text)
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    scales: dict[str, float] = {entry: 1.0}
    order = [entry]
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            called = _op_called(op)
            if op.opcode == "while" and called.get("body") in comps:
                trip = _trip_count(comps[called["condition"]]) \
                    if called.get("condition") in comps else 1
                for sub in ("body", "condition"):
                    nm = called.get(sub)
                    if nm in comps:
                        scales[nm] = scales.get(nm, 0) + scales[cname] * trip
                        order.append(nm)
            elif op.opcode == "call" and called.get("to_apply") in comps:
                nm = called["to_apply"]
                scales[nm] = scales.get(nm, 0) + scales[cname]
                order.append(nm)

    rows = []
    for cname, scale in scales.items():
        comp = comps[cname]
        for op in comp.ops:
            called = _op_called(op)
            if op.opcode in ("while", "call", "conditional"):
                continue
            flops = 0.0
            if op.opcode == "dot":
                flops = _dot_flops(op, comp.table)
            elif op.opcode == "fusion" and called.get("calls") in comps:
                flops = _analyze_comp(called["calls"], comps, {}).flops
            nbytes = 0 if op.opcode in _FREE_OPS else \
                _op_bytes(op, comp, comps, called)
            base = op.opcode.replace("-start", "")
            coll = shape_bytes(op.shape) if base in (
                "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute") and not op.opcode.endswith("-done") \
                else 0
            if nbytes or flops or coll:
                rows.append({
                    "op": op.name, "opcode": op.opcode, "comp": cname,
                    "scale": scale, "bytes": nbytes * scale,
                    "flops": flops * scale, "coll_bytes": coll * scale,
                    "shape": op.shape[:60],
                    "meta": (re.search(r'op_name="([^"]*)"', op.rest)
                             or [None, ""])[1][:90],
                })
    return {
        "by_bytes": sorted(rows, key=lambda r: -r["bytes"])[:top],
        "by_flops": sorted(rows, key=lambda r: -r["flops"])[:top],
        "by_coll": sorted([r for r in rows if r["coll_bytes"]],
                          key=lambda r: -r["coll_bytes"])[:top],
    }
