from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs.registry import (
    ARCH_IDS,
    applicable,
    get_config,
    get_shape,
    get_smoke_config,
)

__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "InputShape", "ModelConfig",
    "applicable", "get_config", "get_shape", "get_smoke_config",
]
