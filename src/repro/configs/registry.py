"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Callable

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

_MODULES = {
    "whisper-base": "repro.configs.whisper_base",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "mamba2-370m": "repro.configs.mamba2_370m",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).smoke_config()


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is part of the dry-run grid; reason if not.

    Skips are documented in DESIGN.md §Arch-applicability.
    """
    if shape.name == "long_500k":
        if cfg.family == "encdec":
            return False, "enc-dec decoder max context << 500k by construction"
        if not cfg.supports_long_context:
            return False, "pure full-attention stack; no sub-quadratic variant"
    return True, ""
