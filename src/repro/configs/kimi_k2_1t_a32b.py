"""kimi-k2-1t-a32b [moe]: trillion-param MoE, 384 experts top-8.

[arXiv:2501.kimi2] Kimi K2 (paper-table entry). 61 layers (first layer
dense FFN), d_model=7168, 64 heads (GQA kv=8 per assignment), expert
d_ff=2048, 384 routed experts top-8 + 1 shared expert, vocab=163840.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="arXiv:2501.kimi2",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18432,              # dense first-layer FFN width (K2 style)
    vocab_size=163_840,
    num_experts=384,
    num_experts_per_tok=8,
    moe_d_ff=2048,
    num_shared_experts=1,
    first_dense_layers=1,
    rope_theta=50_000.0,
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, first_dense_layers=1, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, moe_d_ff=64,
        num_shared_experts=1,
    )
