"""gemma2-9b [dense]: alternating local/global attention + logit softcaps.

[arXiv:2408.00118] Gemma 2. 42 layers, d_model=3584, 16 heads (GQA kv=8),
head_dim=256, d_ff=14336, vocab=256000, window 4096, attn softcap 50,
final logit softcap 30, sandwich norms.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    pattern_period=2,        # local, global, local, global ...
    local_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sandwich_norms=True,
    attn_scale=256 ** -0.5,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, local_window=16,
    )
