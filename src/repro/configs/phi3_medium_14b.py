"""phi3-medium-14b [dense]: RoPE + SwiGLU + GQA, full attention.

[arXiv:2404.14219] Phi-3. 40 layers, d_model=5120, 40 heads (GQA kv=10),
head_dim=128, d_ff=17920, vocab=100352.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    source="arXiv:2404.14219",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100_352,
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
    )
