"""mamba2-370m [ssm]: attention-free SSD (state-space duality).

[arXiv:2405.21060] Mamba-2. 48 layers, d_model=1024 (d_inner=2048,
headdim=64 -> 32 SSM heads), ssm_state=128, vocab=50280.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, ssm_state=16, ssm_head_dim=32,
        vocab_size=512,
    )
