"""zamba2-7b [hybrid]: Mamba2 trunk + shared-weight attention block.

[arXiv:2411.15242] Zamba2. 81 blocks, d_model=3584, attention 32 heads
(MHA, kv=32), d_ff=14336 in the shared block, ssm_state=64, vocab=32000.
We apply the shared attention(+MLP) block every 6th position (13
applications over 81 blocks; remainder 3 blocks are mamba), matching the
paper's periodic shared-block design.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_attn_period=6,
    local_window=4096,       # shared attn block windows at long context
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=6, hybrid_attn_period=3, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        ssm_state=16, ssm_head_dim=32, local_window=16,
    )
