"""granite-moe-1b-a400m [moe]: 32 experts, top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base] 24 layers, d_model=1024,
16 heads (GQA kv=8), expert d_ff=512, 32 experts top-8, vocab=49155.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    num_experts=32,
    num_experts_per_tok=8,
    moe_d_ff=512,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=64, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, moe_d_ff=64,
    )
