"""gemma3-1b [dense]: 5:1 local:global sliding-window stack, 128k-ready.

[hf:google/gemma-3-1b-pt] 26 layers, d_model=1152, 4 heads (GQA kv=1),
head_dim=256, d_ff=6912 (gated), vocab=262144, local window 512,
global layers use rope_theta=1M.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    pattern_period=6,        # 5 local : 1 global
    local_window=512,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    use_qk_norm=True,
    sandwich_norms=True,
    attn_scale=256 ** -0.5,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    # keep the 5:1 pattern visible: 1 superblock of 6 reduces too far;
    # use period 3 (2 local + 1 global) x 2 superblocks.
    return CONFIG.replace(
        num_layers=6, pattern_period=3, d_model=128, num_heads=4,
        num_kv_heads=1, head_dim=32, d_ff=256, vocab_size=512,
        local_window=16,
    )
