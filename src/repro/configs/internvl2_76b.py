"""internvl2-76b [vlm]: InternViT(stub) + LLaMA3-70B-style language trunk.

[arXiv:2404.16821] InternVL2. Vision encoder + MLP projector are STUBS —
``input_specs`` provides precomputed patch embeddings; this config is the
language/decoder transformer that consumes them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    num_image_tokens=256,
    image_embed_dim=3200,  # InternViT-6B width (projector stub input)
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
        num_image_tokens=16, image_embed_dim=96,
    )
