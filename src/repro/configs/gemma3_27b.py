"""gemma3-27b [dense]: 5:1 local:global sliding-window stack, 128k-ready.

[hf:google/gemma-3-1b-pt family] 62 layers, d_model=5376, 32 heads
(GQA kv=16), head_dim=128, d_ff=21504, vocab=262144, window 1024.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    source="hf:google/gemma-3-27b-pt",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    pattern_period=6,
    local_window=1024,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    use_qk_norm=True,
    sandwich_norms=True,
    attn_scale=(5376 / 32) ** -0.5,  # gemma3 query_pre_attn_scalar
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=6, pattern_period=3, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        local_window=16, attn_scale=None,
    )
