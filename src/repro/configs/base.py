"""Model/architecture configuration for the EdgeAI-Hub framework.

Every assigned architecture gets a module in this package exporting
``CONFIG: ModelConfig`` (full-size, dry-run only) and ``smoke_config()``
(reduced variant that runs a real step on CPU).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""       # citation for the config numbers

    # trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024

    # attention pattern: layers come in repeating "periods" of length
    # ``pattern_period``; the LAST layer of each period is global, the
    # rest are local (sliding window).  pattern_period=1 => all global.
    pattern_period: int = 1
    local_window: int = 1024
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None  # gemma3: 1M for globals
    use_qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    sandwich_norms: bool = False  # gemma2/3 post-block norms
    attn_scale: Optional[float] = None  # override 1/sqrt(head_dim)

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # row-wise dispatch: route each sequence independently (vmap over
    # batch) so the expert buffers shard along batch/data instead of a
    # GLOBAL (E, c) buffer every chip must process — see EXPERIMENTS.md
    # §Perf (MoE dispatch).  False = paper-era global dispatch.
    moe_rowwise: bool = False

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2-style): one shared-weight attention block applied
    # every ``hybrid_attn_period``-th block, mamba blocks elsewhere.
    hybrid_attn_period: int = 0

    # encoder-decoder (whisper-style)
    encoder_layers: int = 0
    encoder_seq: int = 0        # precomputed frame embeddings length
    encoder_width: int = 0      # frontend embedding dim (== d_model here)

    # VLM
    num_image_tokens: int = 0
    image_embed_dim: int = 0    # stub projector input dim

    # numerics / misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    use_layernorm: bool = False  # whisper uses LN, everyone else RMSNorm
    use_abs_pos: bool = False    # whisper: sinusoidal/learned positions
    max_target_positions: int = 0  # enc-dec decoder position table size
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # ------------------------------------------------------------------
    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if decode over >=512k context is sub-quadratic/windowed."""
        if self.family in ("ssm", "hybrid"):
            return True
        # dense archs qualify only via a local/global sliding-window stack
        return self.pattern_period > 1

    @property
    def supports_decode(self) -> bool:
        return True  # no encoder-only archs in the assignment

    # layer-pattern bookkeeping -----------------------------------------
    @property
    def num_superblocks(self) -> int:
        return self.pattern_blocks()[0]

    def pattern_blocks(self) -> tuple[int, int]:
        """(num_full_periods, remainder_local_layers) of the decoder trunk."""
        body = self.num_layers - self.first_dense_layers
        if self.pattern_period <= 1:
            return body, 0
        return body // self.pattern_period, body % self.pattern_period

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter counting (analytical; used by perf model & benchmarks) ---
    def param_count(self) -> int:
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d
        if not self.tie_embeddings:
            emb *= 2
        attn = d * self.num_heads * self.head_dim + d * self.head_dim * (
            2 * self.num_kv_heads) + self.num_heads * self.head_dim * d
        dense_mlp = 3 * d * self.d_ff
        if self.family == "ssm":
            per = self._ssm_block_params()
            return emb + L * per
        if self.family == "hybrid":
            n_attn = L // max(self.hybrid_attn_period, 1)
            per_m = self._ssm_block_params()
            shared_attn = attn + 3 * d * self.d_ff
            return emb + (L - n_attn) * per_m + shared_attn
        if self.family == "moe":
            moe_mlp = (self.num_experts + self.num_shared_experts) * 3 * d * self.moe_d_ff
            router = d * self.num_experts
            moe_layers = L - self.first_dense_layers
            return (emb + L * attn + self.first_dense_layers * dense_mlp
                    + moe_layers * (moe_mlp + router))
        if self.family == "encdec":
            enc = self.encoder_layers * (attn + 3 * d * self.d_ff)
            dec = L * (2 * attn + 3 * d * self.d_ff)  # self + cross
            return emb + enc + dec
        # dense / vlm
        return emb + L * (attn + dense_mlp)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d
        attn = d * self.num_heads * self.head_dim + d * self.head_dim * (
            2 * self.num_kv_heads) + self.num_heads * self.head_dim * d
        active_mlp = (self.num_experts_per_tok + self.num_shared_experts) * 3 * d * self.moe_d_ff
        dense_mlp = 3 * d * self.d_ff
        moe_layers = L - self.first_dense_layers
        return (emb + L * attn + self.first_dense_layers * dense_mlp
                + moe_layers * (active_mlp + d * self.num_experts))

    def _ssm_block_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * n + h)
        conv = (di + 2 * n) * self.ssm_conv_width
        out = di * d
        return in_proj + conv + out + 2 * h  # + A, D, dt_bias etc.


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
