"""whisper-base [audio]: enc-dec transformer backbone, conv frontend stubbed.

[arXiv:2212.04356] Radford et al., "Robust Speech Recognition via
Large-Scale Weak Supervision". 6 encoder + 6 decoder layers, d_model=512,
8 heads (MHA; the assignment's GQA kv=8 == MHA here), d_ff=2048,
vocab=51865, 1500 audio frames after the (stubbed) conv frontend.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    source="arXiv:2212.04356",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    encoder_layers=6,
    encoder_seq=1500,
    encoder_width=512,
    use_layernorm=True,
    use_abs_pos=True,
    max_target_positions=448,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, encoder_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        encoder_seq=64, encoder_width=128, max_target_positions=64,
    )
