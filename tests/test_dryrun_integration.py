"""Launch-path integration: run the REAL dry-run in a subprocess (it
must force 512 host devices before jax init, which cannot happen inside
this test process) for a cheap (arch, shape) and check the record."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=REPO)


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_compiles_and_reports(tmp_path, mesh):
    out = tmp_path / "rec.jsonl"
    r = _run_dryrun(["--arch", "mamba2-370m", "--shape", "decode_32k",
                     "--mesh", mesh, "--out", str(out)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["status"] == "ok"
    assert rec["chips"] == (512 if mesh == "multi" else 256)
    for term in ("compute_s", "memory_s", "collective_s"):
        assert rec[term] >= 0.0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert rec["hlo_flops_per_chip"] > 0
    assert "CompiledMemoryStats" in rec["memory_analysis"]


def test_dryrun_documented_skip(tmp_path):
    out = tmp_path / "rec.jsonl"
    r = _run_dryrun(["--arch", "phi3-medium-14b", "--shape", "long_500k",
                     "--mesh", "single", "--out", str(out)])
    assert r.returncode == 0
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["status"] == "skipped"
    assert "full-attention" in rec["reason"]
