# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benchmarks must
# see the host's real single CPU device.  Only launch/dryrun.py forces
# the 512-device placeholder topology (before any jax import).
import os
import time

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _per_test_time_limit(request):
    """Fail any single test that exceeds ``REPRO_TEST_TIME_LIMIT``
    seconds (set by the full ``scripts/check.sh`` gate to 120; unset or
    0 disables).  Slow-test creep is a regression too — a suite the
    inner loop cannot run stops being run."""
    limit = float(os.environ.get("REPRO_TEST_TIME_LIMIT", "0") or 0)
    t0 = time.monotonic()
    yield
    elapsed = time.monotonic() - t0
    if limit > 0 and elapsed > limit:
        pytest.fail(
            f"{request.node.nodeid} took {elapsed:.1f}s "
            f"(> REPRO_TEST_TIME_LIMIT={limit:.0f}s); split it or speed "
            "it up — scripts/check.sh gates on per-test wall time",
            pytrace=False)
