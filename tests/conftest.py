# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benchmarks must
# see the host's real single CPU device.  Only launch/dryrun.py forces
# the 512-device placeholder topology (before any jax import).
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
