"""Per-architecture smoke tests: REDUCED variant of each assigned family
runs a real forward + one train step on CPU; output shapes + no NaNs.
(The FULL configs are exercised via the dry-run only.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, InputShape, get_config, get_smoke_config
from repro.models import model as M
from repro.training import optimizer as opt
from repro.training import trainer as tr

SMOKE = InputShape("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch, rng):
    cfg = M.specialize(get_smoke_config(arch), SMOKE)
    params = M.init_params(cfg, rng)
    batch = M.make_batch(cfg, SMOKE, rng)
    logits, aux = M.apply(cfg, params, batch)
    S_total = SMOKE.seq_len if cfg.family != "vlm" else SMOKE.seq_len
    assert logits.shape == (2, S_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, rng):
    cfg = M.specialize(get_smoke_config(arch), SMOKE)
    tcfg = tr.TrainConfig(
        optimizer=opt.OptimizerConfig(learning_rate=1e-3, warmup_steps=1,
                                      total_steps=10),
        remat=None)
    state = tr.init_train_state(cfg, tcfg, rng)
    step = tr.make_train_step(cfg, tcfg)
    batch = M.make_batch(cfg, SMOKE, rng)
    new_state, metrics = step(state, batch)
    assert float(metrics["loss"]) > 0
    assert not bool(jnp.isnan(metrics["loss"]))
    assert not bool(jnp.isnan(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(new_state["params"])[0]
    assert not bool(jnp.allclose(before, after))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_is_same_family(arch):
    full, smoke = get_config(arch), get_smoke_config(arch)
    assert full.family == smoke.family
    assert smoke.num_layers <= 6 and smoke.d_model <= 512
    if full.family == "moe":
        assert smoke.num_experts <= 4
    # pattern structure preserved where the family has one
    if full.pattern_period > 1:
        assert smoke.pattern_period > 1
    if full.family == "hybrid":
        assert smoke.hybrid_attn_period > 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    }[arch]
    L, d, H, K, ff, V = expected
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == H and cfg.num_kv_heads == K
    assert cfg.vocab_size == V
    if cfg.family == "moe":
        assert cfg.moe_d_ff == ff
    elif cfg.family != "ssm":
        assert cfg.d_ff == ff
    if arch == "kimi-k2-1t-a32b":
        assert cfg.num_experts == 384 and cfg.num_experts_per_tok == 8
        assert cfg.param_count() > 0.9e12  # the paper-table trillion
    if arch == "granite-moe-1b-a400m":
        assert cfg.num_experts == 32 and cfg.num_experts_per_tok == 8
    if arch in ("zamba2-7b",):
        assert cfg.ssm_state == 64
    if arch == "mamba2-370m":
        assert cfg.ssm_state == 128
