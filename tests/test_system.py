"""End-to-end behaviour tests: the full EdgeAI-Hub story in one place —
train -> checkpoint -> deploy -> serve -> schedule -> federate."""
import os

import jax
import numpy as np
import pytest

from repro.configs import InputShape, get_config, get_smoke_config
from repro.core import trustzones as tz
from repro.core.hub import EdgeAIHub
from repro.core.orchestrator import TaskSpec
from repro.data import DataConfig, data_iterator
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig
from repro.training import checkpoint as ckpt
from repro.training import federated as fed
from repro.training.optimizer import OptimizerConfig
from repro.training.trainer import TrainConfig, train_loop


def test_end_to_end_train_checkpoint_serve(tmp_path):
    cfg = get_smoke_config("gemma3-1b")
    shape = InputShape("t", 64, 8, "train")
    tcfg = TrainConfig(optimizer=OptimizerConfig(
        learning_rate=3e-3, warmup_steps=5, total_steps=40), remat=None)
    it = data_iterator(cfg, shape, DataConfig(branching=2))
    state, hist = train_loop(cfg, tcfg, it, 30, log_every=10)
    assert hist[-1]["loss"] < hist[0]["loss"]

    path = os.path.join(tmp_path, "m.npz")
    ckpt.save(path, state["params"])
    params = ckpt.restore(path, state["params"])

    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=2, max_len=96,
                                        prefill_buckets=(8,)))
    for uid in range(4):
        eng.submit(Request(uid=uid,
                           prompt=np.arange(4 + uid, dtype=np.int32),
                           max_new_tokens=6))
    done = eng.run_until_drained()
    assert len(done) == 4


def test_end_to_end_hub_day():
    hub = EdgeAIHub.create(policy="edf")
    full = get_config("gemma3-1b")
    for i in range(8):
        hub.submit(TaskSpec(kind="stream", model=full, batch=1, seq=256,
                            priority=5, deadline_rel=0.25, arrival=i * 0.05,
                            source_device="living-room-tv"))
    hub.submit(TaskSpec(kind="inference", model=full, batch=16, seq=1024,
                        priority=0, deadline_rel=30.0,
                        source_device="alice-phone",
                        data=tz.DataItem("gallery", "household", "alice")))
    hub.orchestrator.fail_device("vacuum")
    report = hub.run()
    assert report["completed"] == 9
    assert report["miss_rate"] <= 0.25


def test_end_to_end_private_federation():
    cfg = get_smoke_config("gemma3-1b")
    shape = InputShape("fl", 32, 4, "train")
    hub = EdgeAIHub.create()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    client_data = {
        n: [next(data_iterator(cfg, shape, DataConfig(seed=i, branching=2)))]
        for i, n in enumerate(["alice-phone", "living-room-tv",
                               "bob-old-phone"])}
    item = tz.DataItem("alice-voice", "personal", "alice")
    new_params, info = hub.federated_round(
        cfg, fed.FedConfig(local_steps=2, local_lr=0.3, dp_clip=1.0,
                           dp_noise_multiplier=0.01,
                           secure_aggregation=True),
        params, client_data, item, round_idx=0)
    # owner gate: bob-old-phone excluded from alice's personal data
    assert len(info["clients"]) == 2
    changed = any(
        not np.allclose(np.asarray(a, np.float32),
                        np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert changed
