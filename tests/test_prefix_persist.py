"""Prefix-store persistence: hot chains survive engine restarts.

Contract under test (ISSUE 5 tentpole, persistence leg): ``close()``
serializes the radix cache's refcount-free chains (token keys + page
bytes) to ``ServeConfig.prefix_persist_path``; a NEW engine constructed
with the same path rehydrates them and serves restart-warm hits that
are BIT-identical to a cold run — while corrupt or mismatched-config
stores are rejected cleanly (fresh cold start, never a crash, never
another model's KV).
"""
import os

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig

ARCH = "phi3-medium-14b"
SHARABLE = ["phi3-medium-14b", "granite-moe-1b-a400m", "internvl2-76b",
            "whisper-base"]


def _family_setup(arch):
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=100.0)   # no token dropping
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _extras(cfg, seed=0):
    rng = np.random.default_rng(seed)
    e = {}
    if cfg.family == "encdec":
        e["audio_embeds"] = rng.normal(
            0, 0.1, (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        e["image_embeds"] = rng.normal(
            0, 0.1, (cfg.num_image_tokens, cfg.image_embed_dim)
        ).astype(np.float32)
    return e


@pytest.fixture(scope="module")
def setup():
    return _family_setup(ARCH)


def _scfg(persist=None, **kw):
    base = dict(max_slots=2, max_len=96, prefill_buckets=(16, 32), seed=5,
                prefix_cache=True, prefix_persist_path=persist)
    base.update(kw)
    return ServeConfig(**base)


def _traffic(cfg, n=3, sys_len=21):
    rng = np.random.default_rng(7)
    sys_prompt = rng.integers(0, cfg.vocab_size, sys_len, dtype=np.int32)
    ext = _extras(cfg)
    reqs = []
    for uid in range(n):
        tail = np.random.default_rng(50 + uid).integers(
            0, cfg.vocab_size, 4 + uid, dtype=np.int32)
        reqs.append(Request(uid=uid,
                            prompt=np.concatenate([sys_prompt, tail]),
                            max_new_tokens=5, extras=dict(ext)))
    return reqs


def _serve(eng, reqs):
    for r in reqs:
        eng.submit(r)
        eng.run_until_drained()
    return {r.uid: tuple(r.generated) for r in reqs}


@pytest.mark.parametrize("arch", SHARABLE)
def test_restart_warm_hit_bit_identical_to_cold(arch, tmp_path):
    cfg, params = _family_setup(arch)
    path = str(tmp_path / "prefix.npz")

    # cold reference (no cache at all)
    cold = _serve(EdgeServingEngine(cfg, params, _scfg(prefix_cache=False)),
                  _traffic(cfg))

    # first engine lifetime: warm the cache, flush it on close
    eng_a = EdgeServingEngine(cfg, params, _scfg(persist=path))
    _serve(eng_a, _traffic(cfg))
    saved = eng_a.close()
    assert saved["persist_saved_chains"] >= 1
    assert saved["persist_saved_blocks"] >= 1
    assert os.path.exists(path)

    # "restarted hub": same config+params+path => rehydrates warm
    eng_b = EdgeServingEngine(cfg, params, _scfg(persist=path))
    assert eng_b.persist_rejected == ""
    assert eng_b.persist_loaded_chains >= 1
    assert eng_b.prefix_cache.num_blocks >= 1
    warm = _serve(eng_b, _traffic(cfg))
    st = eng_b.prefix_cache.stats()
    assert st["hits"] >= len(warm), st     # every request hit the store
    assert warm == cold                    # restart-warm == cold, bitwise
    eng_b.pool.assert_consistent()
    assert (eng_b.pool.num_free + eng_b.prefix_cache.num_blocks
            == eng_b.pool.num_blocks)


def test_corrupt_store_rejected_cleanly(setup, tmp_path):
    cfg, params = setup
    path = str(tmp_path / "prefix.npz")
    with open(path, "wb") as f:
        f.write(b"definitely not a prefix store")
    eng = EdgeServingEngine(cfg, params, _scfg(persist=path))
    assert eng.persist_loaded_chains == 0
    assert "unreadable" in eng.persist_rejected
    assert eng.stats()["persist_rejected"]          # surfaced to operators
    # fresh start still serves correctly
    cold = _serve(EdgeServingEngine(cfg, params, _scfg(prefix_cache=False)),
                  _traffic(cfg, n=1))
    got = _serve(eng, _traffic(cfg, n=1))
    assert got == cold


def test_mismatched_config_and_params_rejected(setup, tmp_path):
    cfg, params = setup
    path = str(tmp_path / "prefix.npz")
    eng_a = EdgeServingEngine(cfg, params, _scfg(persist=path))
    _serve(eng_a, _traffic(cfg, n=2))
    assert eng_a.close()["persist_saved_chains"] >= 1

    # different page geometry: rejected by the header, engine starts cold
    eng_geo = EdgeServingEngine(cfg, params,
                                _scfg(persist=path, kv_block_size=8))
    assert eng_geo.persist_loaded_chains == 0
    assert "mismatched" in eng_geo.persist_rejected

    # different model config (another sharable arch): rejected
    cfg2 = get_smoke_config("granite-moe-1b-a400m")
    params2 = M.init_params(cfg2, jax.random.PRNGKey(0))
    eng_cfg = EdgeServingEngine(cfg2, params2, _scfg(persist=path))
    assert eng_cfg.persist_loaded_chains == 0
    assert "mismatched" in eng_cfg.persist_rejected

    # same config, different weights: the params fingerprint trips —
    # persisted KV bytes are functions of the weights
    params_b = M.init_params(cfg, jax.random.PRNGKey(99))
    eng_w = EdgeServingEngine(cfg, params_b, _scfg(persist=path))
    assert eng_w.persist_loaded_chains == 0
    assert "mismatched" in eng_w.persist_rejected
    # and the reject is non-fatal: it still serves
    got = _serve(eng_w, _traffic(cfg, n=1))
    assert len(got[0]) == 5


def test_quant_restart_warm_matches_quant_cold(setup, tmp_path):
    """int8 pools persist: spilled chains carry the int8 page bytes AND
    the scale leaves, and a restarted quantized engine serves warm hits
    identical to its own cold run (quant-vs-quant — the f32 reference is
    a different numeric system and is gated in the engine matrix)."""
    cfg, params = setup
    path = str(tmp_path / "prefix.npz")

    cold = _serve(EdgeServingEngine(
        cfg, params, _scfg(prefix_cache=False, quant_kv="int8")),
        _traffic(cfg))

    eng_a = EdgeServingEngine(cfg, params,
                              _scfg(persist=path, quant_kv="int8"))
    assert eng_a.quant
    _serve(eng_a, _traffic(cfg))
    saved = eng_a.close()
    assert saved["persist_saved_chains"] >= 1

    eng_b = EdgeServingEngine(cfg, params,
                              _scfg(persist=path, quant_kv="int8"))
    assert eng_b.persist_rejected == ""
    assert eng_b.persist_loaded_chains >= 1
    warm = _serve(eng_b, _traffic(cfg))
    assert eng_b.prefix_cache.stats()["hits"] >= len(warm)
    assert warm == cold                    # restart-warm == cold, bitwise
    eng_b.pool.assert_consistent()


def test_quant_layout_mismatch_rejected(setup, tmp_path):
    """A store written by an f32 engine must not rehydrate into an int8
    pool (or vice versa): the header pins the quant layout, the engine
    rejects cleanly and starts cold."""
    cfg, params = setup
    path_f32 = str(tmp_path / "f32.npz")
    eng_a = EdgeServingEngine(cfg, params, _scfg(persist=path_f32))
    _serve(eng_a, _traffic(cfg, n=2))
    assert eng_a.close()["persist_saved_chains"] >= 1

    # f32 store -> int8 engine: rejected, non-fatal
    eng_q = EdgeServingEngine(cfg, params,
                              _scfg(persist=path_f32, quant_kv="int8"))
    assert eng_q.persist_loaded_chains == 0
    assert "mismatched" in eng_q.persist_rejected
    got = _serve(eng_q, _traffic(cfg, n=1))
    assert len(got[0]) == 5

    # int8 store -> f32 engine: same rejection, opposite direction
    path_q = str(tmp_path / "int8.npz")
    eng_b = EdgeServingEngine(cfg, params,
                              _scfg(persist=path_q, quant_kv="int8"))
    _serve(eng_b, _traffic(cfg, n=2))
    assert eng_b.close()["persist_saved_chains"] >= 1
    eng_f = EdgeServingEngine(cfg, params, _scfg(persist=path_q))
    assert eng_f.persist_loaded_chains == 0
    assert "mismatched" in eng_f.persist_rejected


def test_overlapping_store_rehydrates_without_page_aliasing(setup, tmp_path):
    """Defense in depth for hand-merged / legacy stores: a store holding
    BOTH a partial-tail chain and its extension (close()'s prefix dedup
    never writes one, but load must not trust that) drives insert's
    partial-tail REPLACEMENT path at rehydrate — the superseded page
    returns to the free list mid-load and a later chain's alloc reuses
    it.  The batched scatter must keep the new owner's page bytes
    (last write wins), or warm hits silently decode wrong KV."""
    from repro.serving.prefix_cache import load_store, save_store
    cfg, params = setup
    vocab = cfg.vocab_size
    rng = np.random.default_rng(31)
    S = rng.integers(0, vocab, 21, dtype=np.int32)       # 1 full + partial
    tail = rng.integers(0, vocab, 5, dtype=np.int32)
    other = rng.integers(0, vocab, 30, dtype=np.int32)
    other[0] = (S[0] + 1) % vocab                        # separate subtree

    def chain_store(prompt, name):
        path = str(tmp_path / name)
        eng = EdgeServingEngine(cfg, params, _scfg(persist=path))
        # max_new_tokens=1 finishes at admission: the chain is exactly
        # the prompt tokens (partial tail page included)
        eng.submit(Request(uid=0, prompt=prompt.copy(), max_new_tokens=1))
        eng.run_until_drained()
        eng.close()
        return load_store(path, eng._persist_meta()), eng

    chains_x, eng_ref = chain_store(S, "x.npz")
    chains_y, _ = chain_store(np.concatenate([S, tail]), "y.npz")
    chains_z, _ = chain_store(other, "z.npz")
    merged = str(tmp_path / "merged.npz")
    # X before Y: rehydrating Y upgrades X's partial-tail leaf (frees
    # X's tail page); Z's alloc then reuses the freed ids
    save_store(merged, eng_ref._persist_meta(),
               chains_x + chains_y + chains_z)

    eng = EdgeServingEngine(cfg, params, _scfg(persist=merged))
    assert eng.persist_rejected == ""
    assert eng.persist_loaded_chains == 3
    for probe in (np.concatenate([S, tail, np.asarray([1, 2, 3], np.int32)]),
                  np.concatenate([other, np.asarray([4], np.int32)])):
        cold_eng = EdgeServingEngine(cfg, params,
                                     _scfg(prefix_cache=False))
        r_cold = Request(uid=0, prompt=probe.copy(), max_new_tokens=5)
        cold_eng.submit(r_cold)
        cold_eng.run_until_drained()
        r_warm = Request(uid=1, prompt=probe.copy(), max_new_tokens=5)
        eng.submit(r_warm)
        eng.run_until_drained()
        assert eng.prefix_cache.hits >= 1
        assert tuple(r_warm.generated) == tuple(r_cold.generated), (
            "rehydrated pages served wrong KV", r_warm.generated,
            r_cold.generated)
    eng.pool.assert_consistent()


def test_close_dedups_prefix_and_twin_chains(setup, tmp_path):
    """close() must not write a chain that is a prefix of another
    stored chain (spill-then-extend leaves both around), nor exact
    twins — the store would re-serialize shared bytes and churn the
    pool at rehydrate."""
    cfg, params = setup
    path = str(tmp_path / "prefix.npz")
    eng = EdgeServingEngine(cfg, params, _scfg(persist=path))
    rng = np.random.default_rng(5)
    S = rng.integers(0, cfg.vocab_size, 21, dtype=np.int32)
    eng.submit(Request(uid=0, prompt=S.copy(), max_new_tokens=1))
    eng.run_until_drained()
    # forge the problematic spill state: the resident chain ALSO
    # appears spilled (as its own prefix and as an exact twin)
    resident_key = eng._key_tokens(
        Request(uid=9, prompt=S.copy()))[:21]
    pages = eng._chain_pages_host(eng.prefix_cache._leaves()[0][1].blocks)
    eng._spilled.append((0, resident_key[:16].copy(),
                         [p[:, :1] for p in pages]))      # strict prefix
    eng._spilled.append((0, resident_key.copy(), pages))  # exact twin
    saved = eng.close()
    assert saved["persist_saved_chains"] == 1             # all deduped


def test_pressure_evicted_chains_are_spilled_into_store(setup, tmp_path):
    """Chains evicted under pool pressure DURING serving must still
    reach the close()-time store (host-side spill), not just whatever
    happens to be resident at shutdown."""
    cfg, params = setup
    path = str(tmp_path / "prefix.npz")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, 20 + 3 * i, dtype=np.int32)
               for i in range(6)]
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=2, max_len=64, prefill_buckets=(8, 16, 32),
        kv_block_size=16, kv_pool_blocks=8, seed=0, prefix_cache=True,
        prefix_persist_path=path))
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=p.copy(), max_new_tokens=6))
    eng.run_until_drained()
    assert eng.prefix_cache.evicted_blocks > 0      # pressure really evicted
    assert len(eng._spilled) >= 1                   # ...and was spilled
    saved = eng.close()
    # the store holds more than the resident cache alone could provide
    resident = eng.prefix_cache.num_blocks
    assert saved["persist_saved_chains"] > 0
    assert saved["persist_saved_blocks"] >= resident
