"""Federated substrate: FedAvg, DP clipping, SecAgg mask cancellation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import InputShape, get_smoke_config
from repro.core import trustzones as tz
from repro.core.hub import EdgeAIHub
from repro.data import DataConfig, data_iterator
from repro.models import model as M
from repro.training import federated as fed
from repro.training import optimizer as opt

CFG = get_smoke_config("gemma3-1b")
SHAPE = InputShape("t", 32, 4, "train")


def _client_batches(n_clients, n_batches=2):
    out = {}
    for c in range(n_clients):
        it = data_iterator(CFG, SHAPE, DataConfig(seed=c, branching=2))
        out[c] = [next(it) for _ in range(n_batches)]
    return out


def test_fed_round_improves_loss():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    data = _client_batches(3)
    eval_b = data[0][0]
    before = float(M.loss_fn(CFG, params, eval_b)[0])
    fcfg = fed.FedConfig(local_steps=2, local_lr=0.5)
    for r in range(3):
        params, info = fed.fed_round(CFG, fcfg, params, data, r)
    after = float(M.loss_fn(CFG, params, eval_b)[0])
    assert after < before


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 100))
def test_secagg_masks_cancel_exactly(n_clients, round_seed):
    """Property: Σ masked(delta_i) == Σ delta_i (server never needs the
    individual updates)."""
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((4,), jnp.float32)}
    deltas = {c: jax.tree.map(lambda x, c=c: x * (c + 1), tree)
              for c in range(n_clients)}
    clients = list(deltas)
    masked = {c: fed.secagg_mask(deltas[c], c, clients, round_seed)
              for c in clients}
    plain_sum = jax.tree.map(lambda *xs: sum(xs), *deltas.values())
    masked_sum = jax.tree.map(lambda *xs: sum(xs), *masked.values())
    for a, b in zip(jax.tree.leaves(plain_sum), jax.tree.leaves(masked_sum)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-3)


def test_secagg_individual_updates_are_hidden():
    tree = {"w": jnp.ones((8,), jnp.float32)}
    masked = fed.secagg_mask(tree, 0, [0, 1, 2], round_seed=1)
    assert float(jnp.abs(masked["w"] - tree["w"]).max()) > 0.1


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 5.0))
def test_dp_clip_bounds_norm(clip):
    delta = {"w": jnp.full((32,), 7.0)}
    clipped = fed.clip_update(delta, clip)
    assert float(opt.global_norm(clipped)) <= clip * 1.001


def test_dp_noise_changes_update():
    tree = {"w": jnp.zeros((16,))}
    noisy = fed.add_gaussian_noise(tree, 0.1, jax.random.PRNGKey(0))
    assert float(jnp.abs(noisy["w"]).max()) > 0


def test_hub_federated_round_respects_zones():
    hub = EdgeAIHub.create()
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    client_data = {n: [next(data_iterator(CFG, SHAPE, DataConfig(seed=i)))]
                   for i, n in enumerate(["alice-phone", "bob-phone",
                                          "living-room-tv"])}
    # alice's PERSONAL data: bob's phone must be excluded (owner gate)
    item = tz.DataItem("alice-voice", "personal", "alice")
    new_params, info = hub.federated_round(
        CFG, fed.FedConfig(local_steps=1, local_lr=0.1), params,
        client_data, item)
    assert len(info["clients"]) == 2  # alice-phone + tv; bob-phone gated

    # work data: no work-zone device exists in the home => hard refusal
    with pytest.raises(tz.AccessError):
        hub.federated_round(
            CFG, fed.FedConfig(local_steps=1, local_lr=0.1), params,
            client_data, tz.DataItem("corp-docs", "work", "alice"))


def test_full_private_pipeline():
    """FedAvg + clipping + secagg + DP noise in one round still learns."""
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    data = _client_batches(4)
    fcfg = fed.FedConfig(local_steps=2, local_lr=0.5, dp_clip=5.0,
                         dp_noise_multiplier=0.01, secure_aggregation=True)
    eval_b = data[0][0]
    before = float(M.loss_fn(CFG, params, eval_b)[0])
    for r in range(3):
        params, _ = fed.fed_round(CFG, fcfg, params, data, r)
    after = float(M.loss_fn(CFG, params, eval_b)[0])
    assert after < before
