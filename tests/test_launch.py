"""Launch layer: sharding rules, input specs, HLO analysis.

These run on the single CPU device using AbstractMesh for rule checks
(no XLA_FLAGS forcing — see conftest).  The real 512-device lowering is
exercised by launch/dryrun.py, whose results land in EXPERIMENTS.md.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.compat import PartitionSpec as P
from repro.compat import abstract_mesh
from repro.configs import ARCH_IDS, INPUT_SHAPES, applicable, get_config, \
    get_smoke_config
from repro.launch import sharding as sh
from repro.launch.hlo_analysis import analyze_hlo, parse_module, shape_bytes
from repro.launch.specs import input_specs
from repro.models import model as M

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _check_divisible(shape_tree, spec_tree, mesh):
    def ok(leaf, spec):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            size = sh._axsize(mesh, ax)
            assert leaf.shape[dim] % size == 0, \
                f"{leaf.shape} dim {dim} not divisible by {ax}={size}"
    jax.tree.map(ok, shape_tree, spec_tree,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["single", "multi"])
def test_param_specs_divisible_all_archs(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    for fsdp in (False, True):
        specs = sh.param_pspecs(cfg, mesh, shapes, fsdp=fsdp)
        _check_divisible(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["gemma3-1b", "kimi-k2-1t-a32b",
                                  "mamba2-370m", "zamba2-7b"])
def test_param_specs_actually_shard_big_tensors(arch):
    """Every >=2D tensor with a mesh-divisible dim must not be fully
    replicated (memory correctness at 1T scale)."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    specs = sh.param_pspecs(cfg, MESH, shapes, fsdp=False)

    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    big_replicated = [
        (s.shape, sp) for s, sp in zip(flat_shapes, flat_specs)
        if s.size * 4 > 256e6 and all(a is None for a in sp)]
    assert not big_replicated, f"large replicated tensors: {big_replicated}"


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_cache_and_batch_specs_divisible(arch, shape_name):
    shape = INPUT_SHAPES[shape_name]
    cfg = M.specialize(get_config(arch), shape)
    ok, _ = applicable(cfg, shape)
    if not ok:
        pytest.skip("documented skip")
    specs = input_specs(cfg, shape)
    if shape.kind == "decode":
        cspecs = sh.cache_pspecs(cfg, MESH, specs["cache"],
                                 shape.global_batch)
        _check_divisible(specs["cache"], cspecs, MESH)
    else:
        bspecs = sh.batch_pspecs(cfg, MESH, specs)
        _check_divisible(specs, bspecs, MESH)


def test_input_specs_are_abstract():
    cfg = get_config("gemma3-1b")
    specs = input_specs(cfg, INPUT_SHAPES["decode_32k"])
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)  # no allocation


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------

def test_hlo_analyzer_counts_scan_trip():
    """Dot FLOPs inside a lax.scan are multiplied by the trip count
    (cost_analysis famously counts the body once)."""
    from jax import lax

    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        y, _ = lax.scan(body, x, ws)
        return y.sum()

    xs = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    walked = analyze_hlo(compiled.as_text())
    analytic = 2 * 16 * 64 * 64 * 5
    assert walked.flops == pytest.approx(analytic, rel=0.05)
    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    assert ca["flops"] < walked.flops  # the bug we correct


def test_hlo_analyzer_bytes_sane():
    def f(a, b):
        return a @ b
    A = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    B = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    compiled = jax.jit(f).lower(A, B).compile()
    walked = analyze_hlo(compiled.as_text())
    lo = (128 * 256 + 256 * 128 + 128 * 128) * 4
    assert lo * 0.9 <= walked.bytes <= lo * 3


def test_shape_bytes_parse():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert shape_bytes("pred[]") == 1


def test_applicability_documented_skips():
    skipped = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ok, why = applicable(cfg, INPUT_SHAPES["long_500k"])
        if not ok:
            skipped.append(arch)
            assert why
    assert set(skipped) == {"whisper-base", "internvl2-76b",
                            "kimi-k2-1t-a32b", "granite-moe-1b-a400m",
                            "phi3-medium-14b"}
