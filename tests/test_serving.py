"""Serving engine: continuous batching, priorities, preemption, sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig, \
    cache_batch_axes, insert_slot


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _req(uid, n=6, **kw):
    rng = np.random.default_rng(uid)
    return Request(uid=uid, prompt=rng.integers(0, 64, n, dtype=np.int32),
                   **kw)


def test_engine_drains_all(setup):
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=3, max_len=64,
                                        prefill_buckets=(8, 16)))
    for uid in range(7):
        eng.submit(_req(uid, max_new_tokens=5))
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(len(r.generated) == 5 for r in done)


def test_priority_admission_order(setup):
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=1, max_len=64,
                                        prefill_buckets=(8,)))
    eng.submit(_req(0, max_new_tokens=3, priority=0))
    eng.submit(_req(1, max_new_tokens=3, priority=9))
    eng.submit(_req(2, max_new_tokens=3, priority=5))
    done = eng.run_until_drained()
    assert [r.uid for r in done] == [0, 1, 2][:1] + [1, 2, 0][1:] or \
        [r.uid for r in done][0] in (0, 1)
    # after slot 0 frees, strictly highest priority first
    uids = [r.uid for r in done]
    assert uids.index(1) < uids.index(2) or uids[0] == 1


def test_greedy_is_deterministic(setup):
    cfg, params = setup

    def run():
        eng = EdgeServingEngine(cfg, params,
                                ServeConfig(max_slots=2, max_len=64,
                                            prefill_buckets=(8,)))
        for uid in range(4):
            eng.submit(_req(uid, max_new_tokens=6))
        return [tuple(r.generated) for r in eng.run_until_drained()]
    assert run() == run()


def test_continuous_batching_interleaves(setup):
    """A request submitted mid-flight joins a live batch (slot reuse)."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=2, max_len=64,
                                        prefill_buckets=(8,)))
    eng.submit(_req(0, max_new_tokens=10))
    eng.submit(_req(1, max_new_tokens=2))
    for _ in range(3):
        eng.step()
    assert any(r.uid == 1 for r in eng.completed)
    eng.submit(_req(2, max_new_tokens=2))   # lands in freed slot
    eng.run_until_drained()
    assert {r.uid for r in eng.completed} == {0, 1, 2}
    assert eng.steps < 10 + 2 + 2           # interleaved, not serialized


def test_preempt_and_resume(setup):
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=1, max_len=64,
                                        prefill_buckets=(8, 16)))
    eng.submit(_req(0, max_new_tokens=8))
    eng.step()
    eng.step()
    req = eng.preempt(0)
    assert req is not None and len(req.generated) >= 2
    eng.submit(req)                          # re-admitted with its progress
    done = eng.run_until_drained()
    assert done and done[-1].uid == 0


def test_insert_slot_axes_discovery(setup):
    cfg, params = setup
    axes = cache_batch_axes(cfg, 32)
    leaves = jax.tree.leaves(axes)
    assert all(isinstance(a, int) for a in leaves)
    big = M.init_cache(cfg, 4, 32)
    one = jax.tree.map(lambda x: jnp.ones_like(x),
                       M.init_cache(cfg, 1, 32))
    merged = insert_slot(big, one, 2, axes)
    # slot 2 now holds ones, slot 0 untouched
    k = merged["super"]["local"]["k"]
    assert float(k[0, 0, 2].sum()) != 0.0
    assert float(k[0, 0, 0].sum()) == 0.0


def test_cache_batch_axes_immune_to_dim_collisions(setup):
    """Regression for the sentinel-collision bug: axis discovery used a
    magic batch size (7777) and `shape.index(sentinel)`, which picks the
    WRONG axis whenever any other cache dimension equals the sentinel.
    The two-probe diff is collision-proof: every discovered axis must
    index the true batch dim even when max_len == old sentinel."""
    from functools import partial
    cfg, _ = setup
    for max_len in (7777, 3, 5):    # old sentinel + the probe values
        axes = cache_batch_axes(cfg, max_len)
        shapes = jax.eval_shape(partial(M.init_cache, cfg, 4, max_len))
        ok = jax.tree.map(lambda s, a: s.shape[a] == 4, shapes, axes)
        assert all(jax.tree.leaves(ok)), max_len


def test_preempt_preserves_kv(setup):
    """Preemption carries the slot's cache onto the request: resumed
    decode is token-for-token identical to an uninterrupted run, the
    prompt is untouched, and NO new prefill is compiled on resume."""
    cfg, params = setup
    scfg = ServeConfig(max_slots=1, max_len=64, prefill_buckets=(8, 16))

    eng0 = EdgeServingEngine(cfg, params, scfg)
    eng0.submit(_req(0, max_new_tokens=8))
    baseline = [tuple(r.generated) for r in eng0.run_until_drained()][0]

    eng = EdgeServingEngine(cfg, params, scfg)
    eng.submit(_req(0, max_new_tokens=8))
    eng.step()
    eng.step()
    req = eng.preempt(0)
    assert req is not None and req.saved_state is not None
    assert len(req.prompt) == 6            # prompt NOT rewritten
    n_prefills = len(eng._prefills)
    eng.submit(req)                        # resumes from saved KV
    done = eng.run_until_drained()
    assert len(eng._prefills) == n_prefills  # no re-prefill happened
    assert tuple(done[-1].generated) == baseline


def test_preempt_during_catchup_resumes_exactly(setup):
    """Preempting a slot while its chunked-prefill catch-up is still
    consuming the prompt (pending non-empty) must save the unconsumed
    remainder; re-submission continues token-for-token identical to an
    uninterrupted run, with no new prefill compile."""
    cfg, params = setup
    scfg = ServeConfig(max_slots=1, max_len=96, prefill_buckets=(8, 16))

    eng0 = EdgeServingEngine(cfg, params, scfg)
    eng0.submit(_req(0, n=33, max_new_tokens=6))   # 33 > largest bucket
    baseline = [tuple(r.generated) for r in eng0.run_until_drained()][0]

    eng = EdgeServingEngine(cfg, params, scfg)
    eng.submit(_req(0, n=33, max_new_tokens=6))
    eng.step()
    eng.step()                                     # mid catch-up
    assert eng.pending[0] is not None and eng.pending[0].size
    req = eng.preempt(0)
    assert req.saved_state["pending"].size > 0     # remainder saved
    assert len(req.generated) == 0                 # nothing sampled yet
    n_prefills = len(eng._prefills)
    eng.submit(req)
    done = eng.run_until_drained()
    assert len(eng._prefills) == n_prefills        # no re-prefill
    assert tuple(done[-1].generated) == baseline


def test_submit_rejects_exhausted_resume(setup):
    """A saved state with no room left (pos/pending at the max_len
    wall, or nothing left to generate) is rejected at submit instead of
    burning a prefill-free slot for zero new tokens."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=1, max_len=32,
                                        prefill_buckets=(8,)))
    r = _req(0, max_new_tokens=4)
    r.saved_state = {"pos": 31, "pending": None, "last_tok": 1}
    with pytest.raises(ValueError, match="zero new tokens"):
        eng.submit(r)
    r = _req(1, max_new_tokens=4)
    r.saved_state = {"pos": 20, "pending": np.arange(11, dtype=np.int32),
                     "last_tok": 1}
    with pytest.raises(ValueError, match="zero new tokens"):
        eng.submit(r)                              # catch-up can't fit
    r = _req(2, max_new_tokens=2)
    r.generated = [3, 4]
    r.saved_state = {"pos": 9, "pending": None, "last_tok": 4}
    with pytest.raises(ValueError, match="nothing left"):
        eng.submit(r)
    # a healthy resume at the same positions is still accepted
    eng2 = EdgeServingEngine(cfg, params,
                             ServeConfig(max_slots=1, max_len=32,
                                         prefill_buckets=(8,)))
    eng2.submit(_req(3, max_new_tokens=4))
    eng2.step()
    ok = eng2.preempt(0)
    eng2.submit(ok)
    assert eng2.run_until_drained()


def test_per_request_sampling_params(setup):
    """Request.temperature/top_k override the engine default: top_k=1
    forces greedy even at high temperature, so both requests must agree
    with a pure-greedy engine."""
    cfg, params = setup
    scfg = ServeConfig(max_slots=2, max_len=64, prefill_buckets=(8,),
                       temperature=5.0)   # engine default: very hot
    eng = EdgeServingEngine(cfg, params, scfg)
    eng.submit(_req(0, max_new_tokens=6, temperature=0.0))
    eng.submit(_req(1, max_new_tokens=6, temperature=5.0, top_k=1))
    by_uid = {r.uid: r.generated for r in eng.run_until_drained()}

    ref = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=2, max_len=64,
                                        prefill_buckets=(8,)))
    ref.submit(_req(0, max_new_tokens=6))
    ref.submit(_req(1, max_new_tokens=6))
    ref_by_uid = {r.uid: r.generated for r in ref.run_until_drained()}
    assert by_uid[0] == ref_by_uid[0]
    assert by_uid[1] == ref_by_uid[1]


def test_batched_admission_single_prefill(setup):
    """Same-bucket requests admitted in one step share ONE batched
    prefill call (and one compile)."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=4, max_len=64,
                                        prefill_buckets=(8,)))
    for uid in range(4):
        eng.submit(_req(uid, max_new_tokens=3))
    eng.step()
    assert int(eng.active.sum()) == 4
    assert len(eng._prefills) == 1         # one (bucket=8, m=4) compile
    eng.run_until_drained()
    assert len(eng.completed) == 4


def test_edf_admission_policy(setup):
    """ServeConfig.policy='edf' orders admission by deadline via the
    shared core.scheduler.admission_rank."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=1, max_len=64,
                                        prefill_buckets=(8,), policy="edf"))
    eng.submit(_req(0, max_new_tokens=2, deadline=9.0))
    eng.submit(_req(1, max_new_tokens=2, deadline=1.0))
    eng.submit(_req(2, max_new_tokens=2, deadline=5.0))
    done = eng.run_until_drained()
    assert [r.uid for r in done] == [1, 2, 0]  # earliest deadline first


def test_rejects_oversized_prompt(setup):
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=1, max_len=32,
                                        prefill_buckets=(8,)))
    with pytest.raises(ValueError):
        eng.submit(_req(0, n=40))


@pytest.mark.parametrize("arch", ["mamba2-370m", "granite-moe-1b-a400m",
                                  "whisper-base"])
def test_engine_other_families(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=2, max_len=64,
                                        prefill_buckets=(8,)))
    rng = np.random.default_rng(0)
    for uid in range(3):
        extras = {}
        if cfg.family == "encdec":
            extras["audio_embeds"] = rng.normal(
                0, 0.1, (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, 6,
                                               dtype=np.int32),
                           max_new_tokens=4, extras=extras))
    done = eng.run_until_drained()
    assert len(done) == 3
    assert all(len(r.generated) == 4 for r in done)
