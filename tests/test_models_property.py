"""Property-based tests on model-layer invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCH_IDS, get_config
from repro.models import layers as L

sizes = st.sampled_from([8, 16, 32, 64])


@settings(max_examples=25, deadline=None)
@given(sizes, st.floats(1e3, 1e6))
def test_rope_preserves_norm(d, theta):
    """Rotations never change vector magnitude."""
    x = jax.random.normal(jax.random.PRNGKey(d), (2, 6, 4, d))
    pos = jnp.broadcast_to(jnp.arange(6), (2, 6))
    y = L.apply_rope(x, pos, theta)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


def test_rope_is_relative():
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    d = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))

    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.full((1, 1), i), 10_000.0)
        kj = L.apply_rope(k, jnp.full((1, 1), j), 10_000.0)
        return float(jnp.sum(qi * kj))
    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), rel=1e-3)


@settings(max_examples=25, deadline=None)
@given(sizes, st.floats(-100, 100), st.floats(0.1, 100))
def test_rmsnorm_scale_invariant(d, shift, scale):
    """rmsnorm(c*x) == rmsnorm(x) for any positive c."""
    params = L.init_rmsnorm(d)
    x = jax.random.normal(jax.random.PRNGKey(d), (3, d)) + 0.1
    a = L.rmsnorm(params, x)
    b = L.rmsnorm(params, x * scale)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.floats(1.0, 100.0), st.floats(-1e4, 1e4))
def test_softcap_bounds(cap, v):
    out = float(L._softcap(jnp.float32(v), cap))
    assert abs(out) <= cap * 1.0001
    if abs(v) < cap / 10:            # near-linear region
        assert out == pytest.approx(v, rel=0.05, abs=1e-3)


def test_causal_mask_matches_window_infinite():
    m1 = L.causal_mask(16, 16)
    m2 = L.window_mask(16, 16, window=10**9)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_attention_ignores_future_tokens():
    """Changing token t+1.. never changes output at t (causality)."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("phi3-medium-14b")
    p = L.init_attention(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(12), (1, 12))
    out1, _, _ = L.attention_fwd(cfg, p, x, pos, is_global=True)
    x2 = x.at[:, 8:].set(jax.random.normal(jax.random.PRNGKey(2),
                                           (1, 4, cfg.d_model)))
    out2, _, _ = L.attention_fwd(cfg, p, x2, pos, is_global=True)
    np.testing.assert_allclose(np.asarray(out1[:, :8]),
                               np.asarray(out2[:, :8]), atol=1e-5)


def test_sliding_window_forgets_distant_tokens():
    """With window W, output at t is independent of tokens < t - W."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("gemma2-9b").replace(local_window=4,
                                                use_qk_norm=False)
    p = L.init_attention(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(12), (1, 12))
    out1, _, _ = L.attention_fwd(cfg, p, x, pos, is_global=False)
    x2 = x.at[:, :4].set(0.0)     # mutate tokens far outside the window
    out2, _, _ = L.attention_fwd(cfg, p, x2, pos, is_global=False)
    np.testing.assert_allclose(np.asarray(out1[:, 9:]),
                               np.asarray(out2[:, 9:]), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_param_count_formula_matches_init(seed):
    """Analytic param_count tracks actual init within 5% (smoke sizes)."""
    from repro.configs import get_smoke_config
    from repro.models import model as M
    arch = ARCH_IDS[seed % len(ARCH_IDS)]
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    actual = M.count_params(params)
    analytic = cfg.param_count()
    assert abs(actual - analytic) / actual < 0.25  # norms/bias slack
