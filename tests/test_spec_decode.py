"""Speculative decoding: draft/verify over paged KV + multi-token extend.

The load-bearing guarantees:
* greedy spec output is BIT-identical to vanilla decode per family
  (acceptance only keeps verify-argmax matches, and ``extend_paged``
  reproduces sequential decode exactly);
* a rejected speculation rolls back with zero leaked pages
  (``pool.assert_consistent`` runs inside every ``drain_step``);
* spec coexists with the radix prefix cache (shared pages are
  CoW-forked, hit output == cold output);
* incompatible drafts are rejected at engine construction.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import (EdgeServingEngine, Request, ServeConfig,
                           accept_proposals, make_self_draft,
                           validate_spec)

# one verify arch per spec_decodable family (dense, moe, encdec, vlm)
SPEC_ARCHS = ["phi3-medium-14b", "granite-moe-1b-a400m", "whisper-base",
              "internvl2-76b"]


def _cfg(arch):
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        # extend capacity derives from the static (B*S) token count —
        # ample capacity removes the one legitimate divergence
        cfg = cfg.replace(capacity_factor=100.0)
    return cfg


def _params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _reqs(cfg, lens=(5, 9, 17, 33), max_new=8):
    rng = np.random.default_rng(3)
    out = []
    for uid, n in enumerate(lens):
        extras = {}
        if cfg.family == "encdec":
            extras["audio_embeds"] = rng.normal(
                0, 0.1, (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            extras["image_embeds"] = rng.normal(
                0, 0.1, (cfg.num_image_tokens, cfg.image_embed_dim)
            ).astype(np.float32)
        out.append(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, n,
                                               dtype=np.int32),
                           max_new_tokens=max_new, extras=extras))
    return out


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    return {r.uid: tuple(r.generated) for r in done}


_SCFG = dict(max_slots=4, max_len=96, prefill_buckets=(8, 16))


@pytest.mark.parametrize("arch", SPEC_ARCHS)
def test_spec_greedy_bit_equals_vanilla(arch):
    """Per spec_decodable family: spec on == spec off, token for token,
    under BOTH a high-acceptance draft (the verify model itself — every
    full-sweep/bonus path fires) and a chance-level cross draft (gemma
    smoke — the rejection/rollback path fires almost every round).
    Prompt lengths cross bucket boundaries AND the largest bucket, so
    the multi-token catch-up rides the same waves."""
    cfg = _cfg(arch)
    params = _params(cfg)
    base = _drain(EdgeServingEngine(cfg, params, ServeConfig(**_SCFG)),
                  _reqs(cfg))

    ident = EdgeServingEngine(
        cfg, params, ServeConfig(**_SCFG, spec_decode=True, spec_gamma=4),
        draft=(cfg, params))
    assert _drain(ident, _reqs(cfg)) == base
    st = ident.stats()
    assert st["spec_active"] and st["spec_rounds"] > 0
    assert st["spec_acceptance"] > 0          # self-agreement accepts

    dcfg = get_smoke_config("gemma3-1b")
    dparams = M.init_params(dcfg, jax.random.PRNGKey(9))
    cross = EdgeServingEngine(
        cfg, params, ServeConfig(**_SCFG, spec_decode=True, spec_gamma=4),
        draft=(dcfg, dparams))
    assert _drain(cross, _reqs(cfg)) == base
    st = cross.stats()
    assert st["spec_accepted"] < st["spec_proposed"]  # rejections ran
    if cross.paged:
        assert cross.pool.num_free + (
            cross.prefix_cache.stats()["cached_blocks"]
            if cross.prefix_cache else 0) == cross.pool.num_blocks


def test_spec_dense_twin_matches_paged():
    """spec over the dense (paged=False) engine is wave-for-wave
    identical to the paged one — extend == extend_paged bit-for-bit."""
    cfg = _cfg("phi3-medium-14b")
    params = _params(cfg)
    out = {}
    for paged in (True, False):
        eng = EdgeServingEngine(
            cfg, params,
            ServeConfig(**_SCFG, paged=paged, spec_decode=True,
                        spec_gamma=4),
            draft=(cfg, params))
        out[paged] = (_drain(eng, _reqs(cfg)), eng.stats()["spec_accepted"])
    assert out[True] == out[False]


def test_spec_rejection_rollback_leaks_nothing():
    """A chance-level draft rejects nearly every proposal: every round
    allocates verify-span pages and rolls them back.  assert_consistent
    already runs inside drain_step; afterwards every page must be free
    (prefix cache off so retirement cannot absorb a leak)."""
    cfg = _cfg("phi3-medium-14b")
    params = _params(cfg)
    dcfg = get_smoke_config("gemma2-9b")
    dparams = M.init_params(dcfg, jax.random.PRNGKey(5))
    eng = EdgeServingEngine(
        cfg, params,
        ServeConfig(**_SCFG, prefix_cache=False, spec_decode=True,
                    spec_gamma=4),
        draft=(dcfg, dparams))
    _drain(eng, _reqs(cfg, lens=(5, 9, 13, 21, 33, 7)))
    st = eng.stats()
    assert st["spec_rounds"] > 0
    assert eng.pool.num_free == eng.pool.num_blocks   # zero leaked pages
    assert all(not b for b in eng.slot_blocks)


def test_spec_with_prefix_cache_hit():
    """Spec + radix cache: the second tenant shares the first one's
    prompt pages; a verify wave whose span starts inside a shared page
    must CoW-fork, never write a reader's chain — and hit output equals
    cold output."""
    cfg = _cfg("phi3-medium-14b")
    params = _params(cfg)
    rng = np.random.default_rng(11)
    sys_prompt = rng.integers(0, cfg.vocab_size, 24, dtype=np.int32)

    def req(uid):
        return Request(uid=uid, prompt=sys_prompt.copy(),
                       max_new_tokens=8)

    scfg = ServeConfig(**_SCFG, prefix_cache=True, spec_decode=True,
                       spec_gamma=4)
    eng = EdgeServingEngine(cfg, params, scfg, draft=(cfg, params))
    eng.submit(req(0))
    eng.run_until_drained()                   # cold; chain retired
    hits0 = eng.prefix_cache.hits
    eng.submit(req(1))
    eng.run_until_drained()                   # identical prompt: a hit
    assert eng.prefix_cache.hits > hits0
    by_uid = {r.uid: tuple(r.generated) for r in eng.completed}
    assert by_uid[0] == by_uid[1]             # sharing is invisible
    eng.pool.assert_consistent()

    cold = EdgeServingEngine(cfg, params,
                             ServeConfig(**_SCFG, prefix_cache=False))
    cold.submit(req(2))
    cold.run_until_drained()
    assert tuple(cold.completed[0].generated) == by_uid[0]


def test_spec_preempt_resume_exact():
    """Preempting a speculating slot carries the draft state too;
    resume continues token-for-token (identity draft keeps acceptance
    high so the full-sweep path crosses the preemption)."""
    cfg = _cfg("phi3-medium-14b")
    params = _params(cfg)
    scfg = ServeConfig(max_slots=1, max_len=96, prefill_buckets=(8, 16),
                       spec_decode=True, spec_gamma=4)

    e0 = EdgeServingEngine(cfg, params, scfg, draft=(cfg, params))
    base = _drain(e0, _reqs(cfg, lens=(9,), max_new=12))[0]

    eng = EdgeServingEngine(cfg, params, scfg, draft=(cfg, params))
    req = _reqs(cfg, lens=(9,), max_new=12)[0]
    eng.submit(req)
    eng.step()
    eng.step()
    r = eng.preempt(0)
    assert r.saved_state is not None and "draft" in r.saved_state
    eng.submit(r)
    done = eng.run_until_drained()
    assert tuple(done[-1].generated) == base


def test_vocab_mismatch_rejected_at_validation():
    cfg = _cfg("phi3-medium-14b")          # smoke vocab 512
    params = _params(cfg)
    dcfg = get_smoke_config("gemma3-1b").replace(vocab_size=256)
    dparams = M.init_params(dcfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="vocab mismatch"):
        EdgeServingEngine(cfg, params,
                          ServeConfig(**_SCFG, spec_decode=True),
                          draft=(dcfg, dparams))
    assert validate_spec(cfg, dcfg, 4, 96)  # the shared checker agrees


def test_extras_requiring_draft_rejected_for_text_verify():
    """A vlm/encdec draft prefills from image/audio extras that a
    text-model's requests never carry: rejected at construction, not a
    KeyError mid-admission."""
    cfg = _cfg("phi3-medium-14b")
    params = _params(cfg)
    vcfg = get_smoke_config("internvl2-76b")
    vparams = M.init_params(vcfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="extras"):
        EdgeServingEngine(cfg, params,
                          ServeConfig(**_SCFG, spec_decode=True),
                          draft=(vcfg, vparams))
    # ...while a SAME-family extras draft stays legal (vlm drafts vlm)
    assert not validate_spec(vcfg, vcfg, 4, 96)


def test_gamma_bounds_rejected():
    cfg = _cfg("phi3-medium-14b")
    params = _params(cfg)
    for gamma in (1, 96):
        with pytest.raises(ValueError, match="spec_gamma"):
            EdgeServingEngine(
                cfg, params,
                ServeConfig(**_SCFG, spec_decode=True, spec_gamma=gamma),
                draft=(cfg, params))


def test_spec_quietly_disabled_on_recurrent_families():
    """ssm/hybrid cannot roll back a rejected run: spec_decode=True
    degrades to the vanilla path (mirroring the prefix_cache gate) and
    the engine still drains."""
    for arch in ("mamba2-370m", "zamba2-7b"):
        cfg = get_smoke_config(arch)
        params = _params(cfg)
        eng = EdgeServingEngine(cfg, params,
                                ServeConfig(**_SCFG, spec_decode=True))
        assert eng.spec is None and not eng.extend_ok
        assert not M.spec_decodable(cfg) and not M.extendable(cfg)
        done = _drain(eng, [Request(
            uid=0, prompt=np.arange(6, dtype=np.int32), max_new_tokens=4)])
        assert len(done[0]) == 4
        assert eng.stats()["spec_active"] is False


def test_spec_gated_off_on_local_ring_verify():
    """gemma local rings cannot roll back (a rejected write evicts live
    window context): spec quietly disabled, but multi-token catch-up
    still engages (teacher-forced extend never rolls back)."""
    cfg = get_smoke_config("gemma3-1b")
    params = _params(cfg)
    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(**_SCFG, spec_decode=True))
    assert eng.spec is None and eng.extend_ok
    assert not M.spec_decodable(cfg) and M.extendable(cfg)


def test_self_draft_shares_weights():
    """Self-draft is a view: exit-head norm aside, EVERY draft leaf —
    embeddings, unembed, and the full stacked trunk — is the verify
    model's own device buffer (zero duplicate device bytes; the trunk
    scan slices its trip count in-trace from the draft config)."""
    cfg = _cfg("phi3-medium-14b")
    params = _params(cfg)
    dcfg, dparams = make_self_draft(cfg, params, key=jax.random.PRNGKey(0))
    assert dcfg.num_layers == max(1, cfg.num_layers // 2)
    assert dparams["embed"]["table"] is params["embed"]["table"]
    for a, b in zip(jax.tree.leaves(dparams["trunk"]),
                    jax.tree.leaves(params["trunk"])):
        assert a is b, "self-draft trunk leaf is a copy, not a view"
    for a, b in zip(jax.tree.leaves(dparams["unembed"]),
                    jax.tree.leaves(params["unembed"])):
        assert a is b
    # and the sliced-scan draft still runs: one decode step emits sane
    # logits at the draft's layer count, reading the shared buffer
    dcache = M.init_cache(dcfg, 1, 16)
    logits, _ = M.decode_step(dcfg, dparams, dcache,
                              np.zeros((1, 1), np.int32), 0)
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(np.isfinite(np.asarray(logits)).all())
    with pytest.raises(ValueError, match="self-draft"):
        make_self_draft(get_smoke_config("gemma3-1b"),
                        _params(get_smoke_config("gemma3-1b")))


def test_accept_proposals_rules():
    """The acceptance rule in isolation: greedy exact-match prefix +
    correction; rejection sampling emits from the residual and a clean
    sweep emits the bonus."""
    V = 8
    lg = np.full((3, V), -10.0, np.float32)
    lg[0, 2] = lg[1, 5] = lg[2, 1] = 10.0      # argmax: 2, 5, 1
    rng = np.random.default_rng(0)
    # full sweep: both proposals match -> bonus from row 2
    n, emitted = accept_proposals([2, 5], [None, None], lg, 0.0, 0, rng)
    assert (n, emitted) == (2, [2, 5, 1])
    # first mismatch: correction from row 0, nothing after
    n, emitted = accept_proposals([3, 5], [None, None], lg, 0.0, 0, rng)
    assert (n, emitted) == (0, [2])
    # rejection sampling: draft is certain of a token the target gives
    # zero mass -> always rejected, correction ~ residual == target
    q_target = np.zeros(V)
    q_target[4] = 1.0
    p_draft = np.zeros(V)
    p_draft[0] = 1.0
    lg2 = np.log(np.maximum(q_target, 1e-9))[None, :].repeat(2, axis=0)
    n, emitted = accept_proposals([0], [p_draft], lg2, 1.0, 0, rng)
    assert (n, emitted) == (0, [4])
    # ...and a draft that IS the target distribution always accepts
    n, emitted = accept_proposals([4], [q_target], lg2, 1.0, 0, rng)
    assert n == 1 and emitted[0] == 4 and len(emitted) == 2


def test_rejection_sampling_emits_target_distribution():
    """The rejection-sampling identity: whatever the draft proposes,
    the FIRST emitted token is distributed exactly as vanilla sampling
    from the verify distribution.  Monte-Carlo over the acceptance rule
    with a deliberately mismatched draft."""
    from repro.serving.spec_decode import processed_dist
    rng = np.random.default_rng(0)
    V, temp = 16, 1.0
    verify_logits = rng.normal(0, 2.0, (2, V)).astype(np.float32)
    q = processed_dist(verify_logits[0], temp, 0)
    p = processed_dist(rng.normal(0, 2.0, V).astype(np.float32), temp, 0)
    counts = np.zeros(V)
    n_trials = 20_000
    for _ in range(n_trials):
        d = int(rng.choice(V, p=p))            # proposal ~ draft dist
        _, emitted = accept_proposals([d], [p], verify_logits, temp, 0,
                                      rng)
        counts[emitted[0]] += 1
    tv = 0.5 * np.abs(counts / n_trials - q).sum()
    assert tv < 0.03, tv                        # ~1/sqrt(N) noise floor


def test_extend_paged_matches_sequential_decode():
    """Model-level: one extend_paged call == K sequential paged decode
    steps, logits AND cache bit-for-bit, for every attention family."""
    import jax.numpy as jnp
    for arch in SPEC_ARCHS + ["gemma3-1b"]:
        cfg = _cfg(arch)
        params = _params(cfg)
        rng = np.random.default_rng(0)
        max_len, bs, B, K = 64, 8, 2, 4
        n_blk = max_len // bs
        cache = M.init_paged_cache(cfg, B, max_len, B * n_blk, bs)
        prompt = rng.integers(0, cfg.vocab_size, (B, 6)).astype(np.int32)
        batch = {"tokens": jnp.asarray(prompt)}
        if cfg.family == "encdec":
            batch["audio_embeds"] = jnp.asarray(rng.normal(
                0, .1, (B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.asarray(rng.normal(
                0, .1, (B, cfg.num_image_tokens, cfg.image_embed_dim)),
                jnp.float32)
        prefix = cfg.num_image_tokens if cfg.family == "vlm" else 0
        tables = np.stack([np.arange(n_blk) + i * n_blk
                           for i in range(B)]).astype(np.int32)
        n_wblk = (prefix + 6 + bs - 1) // bs + 1
        _, cache = M.prefill_paged(
            cfg, params, batch, max_len, cache, slots=jnp.arange(B),
            write_tables=jnp.asarray(tables[:, :n_wblk]))
        pos0 = prefix + 6
        toks = rng.integers(0, cfg.vocab_size, (B, K)).astype(np.int32)
        seq, c1 = [], cache
        for i in range(K):
            lg, c1 = M.decode_step_paged(
                cfg, params, c1, jnp.asarray(toks[:, i:i + 1]),
                jnp.full((B,), pos0 + i, jnp.int32), jnp.asarray(tables))
            seq.append(np.asarray(lg[:, -1], np.float32))
        elg, c2 = M.extend_paged(cfg, params, cache, jnp.asarray(toks),
                                 jnp.full((B,), pos0, jnp.int32),
                                 jnp.asarray(tables))
        assert np.array_equal(np.asarray(elg, np.float32),
                              np.stack(seq, 1)), arch
        for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), arch


def test_extend_pad_rows_are_inert():
    """Rows past ``valid_len`` are host padding: their token CONTENT
    must not leak into real rows' logits or the written cache — in
    particular MoE pads must never steal expert capacity (regression:
    at capacity_factor=1.0 a pad duplicating the last real token used
    to overflow its experts and drop a real token's contribution)."""
    import jax.numpy as jnp
    for arch in ("kimi-k2-1t-a32b", "phi3-medium-14b"):
        # capacity_factor=1.0 makes kimi's experts overflow if pads
        # compete (the configuration the bug reproduced on)
        cfg = get_smoke_config(arch).replace(capacity_factor=1.0)
        params = _params(cfg)
        rng = np.random.default_rng(0)
        max_len, bs, B, K = 64, 8, 2, 4
        n_blk = max_len // bs
        tables = np.stack([np.arange(n_blk) + i * n_blk
                           for i in range(B)]).astype(np.int32)
        prompt = rng.integers(0, cfg.vocab_size, (B, 6)).astype(np.int32)
        cache = M.init_paged_cache(cfg, B, max_len, B * n_blk, bs)
        _, cache = M.prefill_paged(
            cfg, params, {"tokens": jnp.asarray(prompt)}, max_len, cache,
            slots=jnp.arange(B), write_tables=jnp.asarray(tables[:, :1]))
        real = rng.integers(0, cfg.vocab_size, (B, 2)).astype(np.int32)
        valid = jnp.full((B,), 2, jnp.int32)

        def run(pad_tok):
            toks = np.concatenate(
                [real, np.full((B, K - 2), pad_tok, np.int32)], axis=1)
            lg, c2 = M.extend_paged(cfg, params, cache,
                                    jnp.asarray(toks),
                                    jnp.full((B,), 6, jnp.int32),
                                    jnp.asarray(tables), valid)
            return np.asarray(lg[:, :2], np.float32), c2

        la, ca = run(int(real[0, -1]))      # pad == last real token
        lb, cb = run(int((real[0, -1] + 1) % cfg.vocab_size))
        assert np.array_equal(la, lb), arch
        for a, b in zip(jax.tree.leaves(ca), jax.tree.leaves(cb)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), arch


def test_catchup_extend_long_prompt_matches_reference():
    """The retired 1-token catch-up: a prompt far past the largest
    bucket now advances spec_gamma tokens per wave and still matches
    the sequential reference engine exactly (greedy)."""
    cfg = _cfg("phi3-medium-14b")
    params = _params(cfg)
    base = _drain(EdgeServingEngine(
        cfg, params, ServeConfig(**_SCFG, spec_gamma=2)),
        _reqs(cfg, lens=(61,)))
    for gamma in (4, 8):
        eng = EdgeServingEngine(cfg, params,
                                ServeConfig(**_SCFG, spec_gamma=gamma))
        assert eng.extend_ok
        assert _drain(eng, _reqs(cfg, lens=(61,))) == base
