"""Cross-feature engine stress matrix.

Every serving feature is pairwise-tested elsewhere (paged vs dense,
prefix-cache hit vs cold, spec vs vanilla, pallas vs gather, policies
vs fifo) — this module turns the crank on the FULL cross product: one
randomized mixed traffic trace (short prompts, bucket-boundary prompts,
prompts past the largest bucket that chunk-catch-up, shared prefixes
that exercise token-granular and in-flight radix hits) replayed through
``ServeConfig`` combos of

    paged x prefix_cache x spec_decode x use_pallas_paged x policy

and asserted TOKEN-FOR-TOKEN equal to the dense vanilla reference
engine, with the pool accounting invariant and a zero-leak check at
drain.  The model runs at float32 so the Pallas paged-attention read is
bit-equal to the jnp gather and greedy argmax never hits an
accumulation tie — any mismatch is a real cross-feature interaction
bug, not noise.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig

ARCH = "phi3-medium-14b"      # fully paged: sharable AND spec-decodable

# (paged, prefix_cache, spec_decode, use_pallas_paged, policy)
COMBOS = [
    (True,  False, False, False, "fifo"),
    (True,  True,  False, False, "priority"),
    (True,  True,  True,  False, "edf"),
    (True,  False, True,  True,  "fifo"),
    (True,  True,  True,  True,  "priority"),
    (True,  True,  False, True,  "edf"),
    (False, True,  True,  False, "edf"),      # dense twin: cache no-ops
    (False, False, False, False, "priority"),
]


def _traffic(vocab):
    """Mixed trace: two shared-prefix families (one ending mid-page),
    a bucket-aligned prompt, and a long prompt that must catch up."""
    rng = np.random.default_rng(42)
    sys_a = rng.integers(0, vocab, 21, dtype=np.int32)   # mid-page prefix
    sys_b = rng.integers(0, vocab, 16, dtype=np.int32)   # page-aligned
    prompts = [
        np.concatenate([sys_a, rng.integers(0, vocab, 4, dtype=np.int32)]),
        np.concatenate([sys_a, rng.integers(0, vocab, 7, dtype=np.int32)]),
        np.concatenate([sys_b, rng.integers(0, vocab, 3, dtype=np.int32)]),
        np.concatenate([sys_b, rng.integers(0, vocab, 9, dtype=np.int32)]),
        rng.integers(0, vocab, 5, dtype=np.int32),       # tiny
        rng.integers(0, vocab, 32, dtype=np.int32),      # largest bucket
        rng.integers(0, vocab, 47, dtype=np.int32),      # chunked catch-up
    ]
    return [Request(uid=uid, prompt=p, max_new_tokens=6,
                    priority=uid % 3, deadline=float(uid))
            for uid, p in enumerate(prompts)]


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config(ARCH).replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    ref = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=3, max_len=96, prefill_buckets=(8, 16, 32), seed=3,
        paged=False, prefix_cache=False, spec_decode=False, policy="fifo"))
    for r in _traffic(cfg.vocab_size):
        ref.submit(r)
    ref.run_until_drained()
    reference = {r.uid: tuple(r.generated) for r in ref.completed}
    assert len(reference) == 7
    return cfg, params, reference


@pytest.mark.parametrize("paged,prefix,spec,pallas,policy", COMBOS)
def test_feature_combo_matches_dense_vanilla(setup, paged, prefix, spec,
                                             pallas, policy):
    cfg, params, reference = setup
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=3, max_len=96, prefill_buckets=(8, 16, 32), seed=3,
        paged=paged, prefix_cache=prefix, spec_decode=spec,
        draft_arch="self", use_pallas_paged=pallas, policy=policy))
    for r in _traffic(cfg.vocab_size):
        eng.submit(r)
    eng.run_until_drained()   # drain_step asserts pool consistency inside
    got = {r.uid: tuple(r.generated) for r in eng.completed}
    assert got == reference, (
        f"token drift vs dense vanilla for paged={paged} prefix={prefix} "
        f"spec={spec} pallas={pallas} policy={policy}")
    stats = eng.stats()       # re-checks pool invariant
    assert stats["steps"] > 0
    if paged:
        # zero leak: every page is free or held by the radix cache
        cached = eng.prefix_cache.num_blocks if eng.prefix_cache else 0
        assert eng.pool.num_free + cached == eng.pool.num_blocks
        if prefix:
            assert eng.sharable and stats["prefix_hits"] >= 1, stats
    else:
        assert eng.prefix_cache is None      # cache gates off with pages
    if spec:
        assert eng.spec is not None and stats["spec_rounds"] >= 1, stats


# ---------------------------------------------------------------------------
# chunked-prefill interleave axis
# ---------------------------------------------------------------------------
# Chunked prompt consumption is config-deterministic but NOT bit-equal
# to monolithic bucketed prefill at f32 (a prompt split across
# prefill(k)+extend(rest) accumulates differently, ~2e-6 max logit
# diff), so the chunked combos gate against a dense vanilla reference
# that chunks with the IDENTICAL wave config — tokens must then match
# exactly: extend is bitwise-equal to sequential decode, so chunk
# boundaries and budget-driven width variation are pure schedule.

CHUNK_WAVE = dict(chunked_prefill=True, catch_chunk=6, wave_tokens=14)


@pytest.fixture(scope="module")
def setup_chunked(setup):
    cfg, params, _ = setup
    ref = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=3, max_len=96, prefill_buckets=(8, 16, 32), seed=3,
        paged=False, prefix_cache=False, spec_decode=False, policy="fifo",
        **CHUNK_WAVE))
    for r in _traffic(cfg.vocab_size):
        ref.submit(r)
    ref.run_until_drained()
    reference = {r.uid: tuple(r.generated) for r in ref.completed}
    assert len(reference) == 7
    assert ref.stats()["wave_admitted"] >= 1    # chunk path exercised
    return reference


# ---------------------------------------------------------------------------
# int8 KV quantization axis
# ---------------------------------------------------------------------------
# quant_kv="int8" is NOT bit-exact vs the f32 dense vanilla reference
# (pages round-trip through int8 + per-row scales), so these combos are
# TOLERANCE-gated instead of token-for-token: greedy decode must track
# the reference for a long common prefix (drift compounds after the
# first flipped argmax, so longest-common-prefix is the right metric)
# and every request's FIRST token must match almost always (cold
# prefill logits never touch quantized bytes — only prefix-cache hit
# suffixes read dequantized pages).  Empirically the smoke config holds
# ~88% LCP / 7-of-7 first tokens; the gate leaves margin.

QUANT_COMBOS = [
    (False, False, False, "fifo"),
    (True,  False, False, "priority"),
    (True,  False, True,  "edf"),
    (False, False, True,  "fifo"),
    (True,  True,  False, "priority"),     # spec verify on quant pages
]


@pytest.mark.parametrize("prefix,spec,pallas,policy", QUANT_COMBOS)
def test_quant_kv_tracks_dense_vanilla(setup, prefix, spec, pallas,
                                       policy):
    cfg, params, reference = setup
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=3, max_len=96, prefill_buckets=(8, 16, 32), seed=3,
        quant_kv="int8", prefix_cache=prefix, spec_decode=spec,
        draft_arch="self", use_pallas_paged=pallas, policy=policy))
    assert eng.quant                       # paged + int8 actually armed
    for r in _traffic(cfg.vocab_size):
        eng.submit(r)
    eng.run_until_drained()
    got = {r.uid: tuple(r.generated) for r in eng.completed}
    assert set(got) == set(reference)
    lcp = total = first = 0
    for uid in reference:
        a, b = reference[uid], got[uid]
        assert len(a) == len(b)
        total += len(a)
        first += a[0] == b[0]
        for x, y in zip(a, b):
            if x != y:
                break
            lcp += 1
    assert first >= len(reference) - 1, (first, got)
    assert lcp >= 0.6 * total, (
        f"quant drift beyond tolerance: lcp {lcp}/{total} for "
        f"prefix={prefix} spec={spec} pallas={pallas} policy={policy}")
    stats = eng.stats()
    assert stats["quant_kv"] == "int8"
    assert stats["quant_page_bytes"] < stats["quant_f32_page_bytes"]
    cached = eng.prefix_cache.num_blocks if eng.prefix_cache else 0
    assert eng.pool.num_free + cached == eng.pool.num_blocks
    if spec:
        assert eng.spec is not None and stats["spec_rounds"] >= 1, stats


def test_quant_draft_greedy_is_bit_exact(setup):
    """int8 draft weights change PROPOSALS only: greedy speculative
    output is decided by the (f32) verify trunk, so tokens must equal
    the dense vanilla reference token-for-token even with a quantized
    draft — a worse draft can only cost acceptance rate."""
    cfg, params, reference = setup
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=3, max_len=96, prefill_buckets=(8, 16, 32), seed=3,
        spec_decode=True, draft_arch="gemma3-1b", quant_draft=True,
        policy="fifo"))
    for r in _traffic(cfg.vocab_size):
        eng.submit(r)
    eng.run_until_drained()
    got = {r.uid: tuple(r.generated) for r in eng.completed}
    assert got == reference, "quantized draft leaked into verify output"
    stats = eng.stats()
    assert stats["quant_draft"] is True and stats["spec_rounds"] >= 1


def test_quant_config_validation():
    """Misconfigurations fail loudly at engine construction."""
    cfg = get_smoke_config(ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="quant_kv"):
        EdgeServingEngine(cfg, params, ServeConfig(quant_kv="int4"))
    with pytest.raises(ValueError, match="quant_draft"):
        EdgeServingEngine(cfg, params, ServeConfig(
            spec_decode=True, draft_arch="self", quant_draft=True))
    with pytest.raises(ValueError, match="quant_draft"):
        EdgeServingEngine(cfg, params, ServeConfig(quant_draft=True))


def test_quant_kv_off_on_nonpaged_families():
    """ssm/hybrid silently serve dense: quant_kv is accepted but the
    engine reports the quant machinery disarmed (no pages to quantize)."""
    cfg = get_smoke_config("mamba2-370m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=2, max_len=64, quant_kv="int8"))
    assert not eng.paged and not eng.quant
    r = Request(uid=0, prompt=np.arange(4, 12, dtype=np.int32),
                max_new_tokens=4)
    eng.submit(r)
    eng.run_until_drained()
    assert len(r.generated) == 4


@pytest.mark.parametrize("paged,prefix,spec,pallas,policy", COMBOS)
def test_chunked_interleave_matches_chunked_dense(setup, setup_chunked,
                                                  paged, prefix, spec,
                                                  pallas, policy):
    """Same 8 combos with prompts admitted as wave spans interleaved
    with decode under a shared per-wave token budget."""
    cfg, params, _ = setup
    reference = setup_chunked
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=3, max_len=96, prefill_buckets=(8, 16, 32), seed=3,
        paged=paged, prefix_cache=prefix, spec_decode=spec,
        draft_arch="self", use_pallas_paged=pallas, policy=policy,
        **CHUNK_WAVE))
    for r in _traffic(cfg.vocab_size):
        eng.submit(r)
    eng.run_until_drained()
    got = {r.uid: tuple(r.generated) for r in eng.completed}
    assert got == reference, (
        f"token drift vs chunked dense vanilla for paged={paged} "
        f"prefix={prefix} spec={spec} pallas={pallas} policy={policy}")
    stats = eng.stats()
    assert stats["wave_admitted"] >= 1
    assert stats["mixed_waves"] >= 1            # prefill rode a decode wave
    if paged:
        cached = eng.prefix_cache.num_blocks if eng.prefix_cache else 0
        assert eng.pool.num_free + cached == eng.pool.num_blocks
