"""EdgeAI-Hub core: scheduler, orchestrator, placement, network, zones."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import trustzones as tz
from repro.core.network import CHANNEL_CATALOGUE, MultiChannelLink
from repro.core.orchestrator import Orchestrator, TaskSpec
from repro.core.hub import EdgeAIHub, default_home
from repro.core.placement import PlacementOption, greedy_partition, \
    solve_knapsack
from repro.core.perf_model import DEVICE_CATALOGUE, estimate, inference_cost
from repro.core.scheduler import AITask, EdgeScheduler
from repro.configs import get_config, get_smoke_config


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _tasks(durations, device="d0", **kw):
    return [AITask(uid=i, kind="inference", duration_s=d, device=device, **kw)
            for i, d in enumerate(durations)]


def test_fifo_order():
    s = EdgeScheduler("fifo")
    for t in _tasks([1.0, 1.0, 1.0]):
        s.submit(t)
    done = s.run()
    assert [t.uid for t in done] == [0, 1, 2]


def test_qoe_p99_is_ceil_quantile():
    """Regression: the old p99 index ``int(0.99*n) - 1`` was biased LOW
    for small samples (n=2 reported the MINIMUM latency as "p99").  The
    tail quantile must match np.percentile(..., method='higher')."""
    import numpy as np
    from repro.core.scheduler import quantile_higher

    rng = np.random.default_rng(0)
    for n in range(1, 12):
        vals = rng.uniform(0.1, 9.0, n).tolist()
        expect = float(np.percentile(vals, 99, method="higher"))
        assert quantile_higher(vals, 0.99) == pytest.approx(expect), n

    # end-to-end: two sequential tasks — p99 latency is the LONGER one
    s = EdgeScheduler("fifo")
    for t in _tasks([1.0, 3.0]):
        s.submit(t)
    s.run()
    rep = s.qoe_report()
    assert rep["p99_latency_s"] == pytest.approx(4.0)  # 1.0 wait + 3.0
    with pytest.raises(ValueError):
        quantile_higher([], 0.99)


def test_priority_preemption():
    s = EdgeScheduler("priority")
    low = AITask(uid=0, kind="inference", duration_s=10.0, device="d",
                 priority=0, arrival=0.0)
    high = AITask(uid=1, kind="stream", duration_s=1.0, device="d",
                  priority=5, arrival=2.0)
    s.submit(low)
    s.submit(high)
    done = s.run()
    assert done[0].uid == 1 and done[0].finish_time == pytest.approx(3.0)
    assert done[1].preemptions == 1
    assert done[1].finish_time == pytest.approx(11.0)  # banked progress


def test_edf_meets_feasible_deadlines():
    s = EdgeScheduler("edf")
    s.submit(AITask(uid=0, kind="i", duration_s=2.0, device="d",
                    deadline=10.0, arrival=0.0))
    s.submit(AITask(uid=1, kind="i", duration_s=1.0, device="d",
                    deadline=2.0, arrival=0.5))
    done = s.run()
    assert all(not t.missed_deadline for t in done)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(
    st.floats(0.01, 5.0),      # duration
    st.floats(0.0, 10.0),      # arrival
    st.integers(0, 3)),        # priority
    min_size=1, max_size=12))
def test_scheduler_invariants(spec):
    """Property: every task completes exactly once, start >= arrival,
    finish = start + total duration accounting preemption gaps, and the
    device never runs two tasks at once."""
    s = EdgeScheduler("priority")
    for i, (dur, arr, pri) in enumerate(spec):
        s.submit(AITask(uid=i, kind="i", duration_s=dur, device="d",
                        arrival=arr, priority=pri))
    done = s.run()
    assert sorted(t.uid for t in done) == list(range(len(spec)))
    for t in done:
        assert t.start_time >= t.arrival - 1e-9
        assert t.finish_time >= t.start_time + t.duration_s - 1e-6
    # non-overlap of execution on the single device
    spans = []
    running = {}
    for time_, ev, uid, dev in s.trace:
        if ev == "start":
            running[uid] = time_
        elif ev in ("preempt", "finish"):
            spans.append((running.pop(uid), time_))
    spans.sort()
    for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
        assert s1 >= e0 - 1e-9


def test_scheduler_determinism():
    def run():
        s = EdgeScheduler("edf")
        for i in range(8):
            s.submit(AITask(uid=i, kind="i", duration_s=0.5 + i * 0.1,
                            device="d", arrival=i * 0.2,
                            deadline=i * 0.2 + 3, priority=i % 2))
        s.run()
        return [(t.uid, t.finish_time) for t in s.completed]
    assert run() == run()


# ---------------------------------------------------------------------------
# orchestrator: placement, trust zones, fault tolerance
# ---------------------------------------------------------------------------

def test_orchestrator_places_training_on_hub():
    hub = EdgeAIHub.create()
    spec = TaskSpec(kind="training", model=get_smoke_config("gemma3-1b"),
                    batch=8, seq=128)
    placement = hub.orchestrator.place(spec)
    assert placement.device == "hub"  # only train-capable device


def test_orchestrator_respects_zones():
    hub = EdgeAIHub.create()
    data = tz.DataItem("alice-health", "personal", "alice")
    spec = TaskSpec(kind="inference", model=get_smoke_config("gemma3-1b"),
                    batch=1, seq=64, data=data)
    placement = hub.orchestrator.place(spec)
    owner = hub.registry.get(placement.device).owner
    zone = hub.registry.get(placement.device).zone
    assert tz.allowed(data, placement.device, zone, owner)
    assert placement.device not in ("bob-phone", "bob-old-phone")


def test_fault_tolerance_reassigns():
    hub = EdgeAIHub.create()
    cfg = get_smoke_config("gemma3-1b")
    uid = hub.submit(TaskSpec(kind="inference", model=cfg, batch=64,
                              seq=2048, arrival=0.0))
    placement = hub.orchestrator._task_meta[uid][1]
    moved = hub.orchestrator.fail_device(placement.device)
    assert moved  # task was re-placed
    new_dev = hub.orchestrator._task_meta[moved[0]][1].device
    assert new_dev != placement.device
    rep = hub.run()
    assert rep["completed"] >= 1


def test_historical_estimator_updates():
    hub = EdgeAIHub.create()
    cfg = get_smoke_config("gemma3-1b")
    for _ in range(3):
        hub.submit(TaskSpec(kind="inference", model=cfg, batch=1, seq=64))
    hub.run()
    key = hub.orchestrator._task_kind(
        TaskSpec(kind="inference", model=cfg, batch=1, seq=64))
    devs = [n for n in hub.registry.names()
            if hub.orchestrator.history.predict(key, n) is not None]
    assert devs


# ---------------------------------------------------------------------------
# placement knapsack
# ---------------------------------------------------------------------------

def test_knapsack_beats_greedy_or_ties():
    opts = [
        PlacementOption("hub", "npu-train", cost=8, utility=10.0),
        PlacementOption("hub", "npu-infer", cost=4, utility=6.0),
        PlacementOption("phone", "npu-infer", cost=5, utility=5.5),
        PlacementOption("tv", "npu-infer", cost=3, utility=3.0),
        PlacementOption("sensor", "none", cost=0, utility=0.5),
    ]
    exact, u_exact = solve_knapsack(opts, budget=12)
    greedy, u_greedy = greedy_partition(opts, budget=12)
    assert u_exact >= u_greedy - 1e-9
    assert sum(o.cost for o in exact) <= 12
    devices = [o.device for o in exact]
    assert len(devices) == len(set(devices))  # one option per device


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(1, 9),
                          st.floats(0.1, 10)), min_size=1, max_size=8),
       st.integers(1, 20))
def test_knapsack_feasible_and_optimal_vs_greedy(items, budget):
    opts = [PlacementOption(f"d{d}", "acc", cost=c, utility=u)
            for d, c, u in items]
    exact, u_exact = solve_knapsack(opts, budget)
    assert sum(o.cost for o in exact) <= budget
    _, u_greedy = greedy_partition(opts, budget)
    assert u_exact >= u_greedy - 1e-9


# ---------------------------------------------------------------------------
# network
# ---------------------------------------------------------------------------

def test_multichannel_striping_beats_single():
    link = MultiChannelLink([CHANNEL_CATALOGUE["wifi6"],
                             CHANNEL_CATALOGUE["5g-local"]])
    payload = 100e6  # 100 MB
    striped = link.send(payload).latency_s
    _, single = link.best_single_channel(payload)
    assert striped < single


def test_bandwidth_slicing():
    link = MultiChannelLink([CHANNEL_CATALOGUE["wifi6"]])
    assert link.reserve("stream", 0.6)
    assert not link.reserve("other", 0.6)   # over-subscribed
    assert link.reserve("other", 0.4)
    link.release("stream")
    assert link.reserve("third", 0.5)


def test_small_payload_prefers_low_latency_channel():
    link = MultiChannelLink([CHANNEL_CATALOGUE["wifi-legacy"],
                             CHANNEL_CATALOGUE["uwb"]])
    ch, _ = link.best_single_channel(100.0)       # 100 B ping
    assert ch.name == "uwb"
    ch, _ = link.best_single_channel(500e6)       # bulk transfer
    assert ch.name == "wifi-legacy"


# ---------------------------------------------------------------------------
# trust zones
# ---------------------------------------------------------------------------

def test_zone_lattice():
    pol = tz.ZonePolicy()
    assert pol.zone_allows("public", "household")
    assert pol.zone_allows("personal", "personal")
    assert not pol.zone_allows("work", "household")
    assert not pol.zone_allows("household", "work")


def test_acl_overrides():
    d = tz.DataItem("doc", "work", "alice",
                    acl_allow=frozenset({"hub"}),
                    acl_deny=frozenset({"bob-phone"}))
    assert tz.allowed(d, "hub", "household", "alice")       # explicit allow
    assert not tz.allowed(d, "bob-phone", "work", "bob")    # explicit deny
    assert not tz.allowed(d, "tv", "household", "alice")    # zone blocks


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(["personal", "household", "work", "public"]),
       st.sampled_from(["personal", "household", "work", "public"]),
       st.booleans())
def test_personal_data_never_leaves_owner(data_zone, dev_zone, same_owner):
    d = tz.DataItem("x", data_zone, "alice")
    owner = "alice" if same_owner else "eve"
    if data_zone == "personal" and not same_owner:
        assert not tz.allowed(d, "dev", dev_zone, owner)


def test_check_raises():
    d = tz.DataItem("x", "work", "alice")
    with pytest.raises(tz.AccessError):
        tz.check(d, "tv", "household", "alice")
