"""Telemetry subsystem tests: the metrics registry and tracer as pure
units (fake clock — no wall time anywhere), the Chrome-trace export
contract, and the engine integration gates from ISSUE 9:

* ``stats()`` schema snapshots per config axis (paged / prefix / spec /
  quant) — a PR silently dropping or renaming a counter fails loudly;
* registry-backed ``stats()`` equals the pre-refactor ad-hoc dict,
  recomputed from the same engine attributes, on a mixed traffic trace;
* tracing is behaviour-neutral: trace-on tokens bit-identical to
  trace-off, and the dump is valid Chrome-trace JSON whose per-request
  TTFT decomposition sums exactly.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig
from repro.serving.telemetry import (Counter, Gauge, Histogram,
                                     MetricsRegistry, Tracer,
                                     validate_chrome_trace,
                                     summarize_trace)

ARCH = "phi3-medium-14b"   # fully paged: sharable AND spec-decodable


# ---------------------------------------------------------------------------
# metrics registry (pure units)
# ---------------------------------------------------------------------------

def test_counter_monotonic():
    c = Counter("x")
    c.inc()
    c.inc(3)
    assert c.read() == 4
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_vs_callback():
    g = Gauge("x")
    g.set(7)
    assert g.read() == 7
    cb = Gauge("y", fn=lambda: 42)
    assert cb.read() == 42
    with pytest.raises(ValueError):
        cb.set(1)          # callback-sampled gauges reject direct set


def test_gauge_latest_callback_wins():
    m = MetricsRegistry()
    m.gauge("fe.streams", lambda: 1)
    # a second frontend re-attaching to the same engine must not leave
    # the gauge bound to the dead frontend's closure
    m.gauge("fe.streams", lambda: 2)
    assert m.get("fe.streams") == 2


def test_histogram_buckets_fixed_and_validated():
    with pytest.raises(ValueError):
        Histogram("bad", ())
    with pytest.raises(ValueError):
        Histogram("bad", (3, 2, 1))
    h = Histogram("h", (1, 2, 4))
    for v in (0.5, 1.0, 3.0, 99.0):
        h.observe(v)
    r = h.read()
    assert r["buckets"] == [1.0, 2.0, 4.0]
    assert r["counts"] == [2, 0, 1, 1]      # <=1, <=2, <=4, overflow
    assert r["count"] == 4 and r["sum"] == pytest.approx(103.5)


def test_registry_get_or_create_and_type_clash():
    m = MetricsRegistry()
    assert m.counter("a") is m.counter("a")     # idempotent
    with pytest.raises(ValueError):
        m.gauge("a")                            # type clash
    assert "a" in m and "b" not in m
    m.gauge("g", lambda: 5)
    m.histogram("h", (1,)).observe(0)
    snap = m.collect()
    assert list(snap) == sorted(snap)           # deterministic order
    assert snap["a"] == 0 and snap["g"] == 5
    assert snap["h"]["count"] == 1


# ---------------------------------------------------------------------------
# tracer (fake clock)
# ---------------------------------------------------------------------------

def make_clock(step_s: float = 0.001):
    t = [0.0]

    def clock():
        t[0] += step_s
        return t[0]
    return clock


def test_spans_nest_and_validate():
    tr = Tracer(clock=make_clock())
    with tr.span("outer", step=1):
        with tr.span("inner"):
            tr.instant("mark")
    tr.begin("u7", tid=10)
    tr.end(10)
    tr.end(10)                        # idempotent: no unmatched E
    evs = tr.chrome_events()
    assert validate_chrome_trace(evs) == []
    xs = [e for e in evs if e["ph"] == "X"]
    inner = next(e for e in xs if e["name"] == "inner")
    outer = next(e for e in xs if e["name"] == "outer")
    # proper nesting: inner fully inside outer
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert outer["args"] == {"step": 1}


def test_open_residencies_auto_close():
    tr = Tracer(clock=make_clock())
    tr.begin("u1", tid=10)
    tr.begin("u2", tid=11)
    assert validate_chrome_trace(tr.chrome_events()) == []


def test_request_summary_decomposition_exact():
    tr = Tracer(clock=make_clock())
    tr.req_event(5, "submit")
    tr.req_event(5, "queued", depth=1)
    tr.req_event(5, "admitted", slot=0)
    tr.req_event(5, "prefill_chunk", n=8)
    tr.req_event(5, "prompt_done")
    tr.req_event(5, "first_token")
    tr.req_event(5, "tokens", n=1)
    tr.req_event(5, "spec_round", proposed=3, accepted=2)
    tr.req_event(5, "tokens", n=3)
    tr.req_event(5, "finish", n_generated=4)
    (row,) = tr.request_summaries()
    assert row["uid"] == 5
    # segments share boundary stamps -> sum is exact, not approximate
    assert (row["queue_wait_us"] + row["prefill_us"]
            + row["first_wave_us"]) == row["ttft_us"]
    assert row["e2e_us"] >= row["ttft_us"]
    assert row["n_tokens"] == 4
    # tokens retired by one wave share a stamp: the wave gap is > 0,
    # intra-wave gaps are exactly 0
    assert len(row["itl_us"]) == 3
    assert row["itl_us"][0] > 0 and row["itl_us"][1:] == [0.0, 0.0]
    assert row["spec_rounds"] == [(3, 2)]


def test_dump_and_summarize_roundtrip(tmp_path):
    tr = Tracer(clock=make_clock())
    with tr.span("step"):
        tr.req_event(0, "submit")
        tr.req_event(0, "finish")
    path = tmp_path / "t.json"
    meta = tr.dump_chrome_trace(str(path))
    trace = json.loads(path.read_text())
    assert len(trace["traceEvents"]) == meta["events"]
    s = summarize_trace(trace)
    assert s["problems"] == []
    assert s["phases"][0]["name"] == "step"
    assert len(s["requests"]) == 1


def test_validator_catches_structural_breaks():
    assert validate_chrome_trace([{"ph": "B", "ts": 0}])  # missing keys
    bad = [{"ph": "E", "ts": 0, "pid": 0, "tid": 3}]
    assert any("without matching B" in p
               for p in validate_chrome_trace(bad))
    open_b = [{"ph": "B", "name": "u", "ts": 0, "pid": 0, "tid": 3}]
    assert any("unclosed" in p for p in validate_chrome_trace(open_b))


# ---------------------------------------------------------------------------
# scheduler budget metrics
# ---------------------------------------------------------------------------

def test_plan_wave_records_budget_metrics():
    from repro.core.scheduler import plan_wave
    m = MetricsRegistry()
    entries = [{"id": 0, "want": 4, "uid": 0},
               {"id": 1, "want": 4, "uid": 1}]
    widths = plan_wave("fifo", entries, budget=5, metrics=m)
    assert sum(widths.values()) == 5
    h = m.get("sched.budget_utilization")
    assert h["count"] == 1 and h["sum"] == pytest.approx(1.0)
    assert m.get("sched.demotions") >= 1      # someone got < want
    # unbudgeted plans record nothing
    plan_wave("fifo", entries, budget=None, metrics=m)
    assert m.get("sched.budget_utilization")["count"] == 1


# ---------------------------------------------------------------------------
# stats() schema snapshots per config axis
# ---------------------------------------------------------------------------

ENGINE_KEYS = ("steps", "peak_active", "peak_pool_used",
               "exhaust_preempts", "reclaims", "cow_forks", "mixed_waves",
               "wave_admitted", "cancels")
POOL_KEYS = ("pool_blocks", "pool_free", "pool_shared")
QUANT_KEYS = ("quant_kv", "quant_draft", "quant_page_bytes",
              "quant_f32_page_bytes")
SPEC_KEYS = ("spec_active", "spec_steps", "spec_rounds", "spec_proposed",
             "spec_accepted", "spec_emitted", "spec_acceptance",
             "spec_tokens_per_round")
PREFIX_KEYS = ("prefix_hits", "prefix_misses", "prefix_hit_rate",
               "prefix_hit_blocks", "prefix_hit_tokens",
               "prefix_hit_tokens_block", "prefix_cached_blocks",
               "prefix_evicted_blocks", "prefix_inserted_blocks",
               "prefix_replaced_blocks", "prefix_short_matches",
               "published_frontiers")

SCHEMA_AXES = [
    # (tag, scfg kwargs, expected stats() key tuple)
    ("dense", dict(paged=False, prefix_cache=False), ENGINE_KEYS),
    ("paged", dict(prefix_cache=False), ENGINE_KEYS + POOL_KEYS),
    ("prefix", dict(prefix_cache=True),
     ENGINE_KEYS + POOL_KEYS + PREFIX_KEYS),
    ("spec", dict(prefix_cache=False, spec_decode=True,
                  draft_arch="self"),
     ENGINE_KEYS + POOL_KEYS + SPEC_KEYS),
    ("quant", dict(prefix_cache=False, quant_kv="int8"),
     ENGINE_KEYS + POOL_KEYS + QUANT_KEYS),
]


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config(ARCH).replace(dtype="float32")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.mark.parametrize("tag,kw,expected",
                         SCHEMA_AXES, ids=[a[0] for a in SCHEMA_AXES])
def test_stats_schema_snapshot(model, tag, kw, expected):
    cfg, params = model
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=2, max_len=96, prefill_buckets=(8, 16, 32), **kw))
    assert tuple(eng.stats().keys()) == expected, tag


# ---------------------------------------------------------------------------
# engine integration: trace neutrality + registry-backed stats()
# ---------------------------------------------------------------------------

def _traffic(vocab):
    rng = np.random.default_rng(42)
    shared = rng.integers(0, vocab, 21, dtype=np.int32)
    prompts = [
        np.concatenate([shared,
                        rng.integers(0, vocab, 4, dtype=np.int32)]),
        np.concatenate([shared,
                        rng.integers(0, vocab, 7, dtype=np.int32)]),
        rng.integers(0, vocab, 5, dtype=np.int32),
        rng.integers(0, vocab, 41, dtype=np.int32),   # chunked catch-up
    ]
    return [Request(uid=u, prompt=p, max_new_tokens=5, priority=u % 3,
                    deadline=float(u)) for u, p in enumerate(prompts)]


def _run(cfg, params, trace, clock=None):
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=2, max_len=96, prefill_buckets=(8, 16, 32), seed=3,
        paged=True, prefix_cache=True, spec_decode=True,
        draft_arch="self", policy="priority",
        trace=trace, trace_clock=clock))
    for r in _traffic(cfg.vocab_size):
        eng.submit(r)
    eng.run_until_drained()
    return eng


@pytest.fixture(scope="module")
def traced_pair(model):
    cfg, params = model
    untraced = _run(cfg, params, trace=False)
    traced = _run(cfg, params, trace=True, clock=make_clock(1e-4))
    return untraced, traced


def test_tracing_is_behaviour_neutral(traced_pair):
    untraced, traced = traced_pair
    t0 = {r.uid: tuple(r.generated) for r in untraced.completed}
    t1 = {r.uid: tuple(r.generated) for r in traced.completed}
    assert t0 == t1
    assert untraced.stats() == traced.stats()


def test_stats_equals_pre_refactor_dict(traced_pair):
    """The compatibility view must reproduce the historical ad-hoc
    dict — recomputed here from the same engine attributes the old
    ``stats()`` read directly."""
    eng, _ = traced_pair
    expected = {
        "steps": eng.steps,
        "peak_active": eng.peak_active,
        "peak_pool_used": eng.peak_pool_used,
        "exhaust_preempts": eng.exhaust_preempts,
        "reclaims": eng.reclaims,
        "cow_forks": eng.cow_forks,
        "mixed_waves": eng.mixed_waves,
        "wave_admitted": eng.wave_admitted,
        "cancels": eng.cancels,
        "pool_blocks": eng.pool.num_blocks,
        "pool_free": eng.pool.num_free,
        "pool_shared": eng.pool.num_shared,
        "spec_active": eng.spec is not None,
        "spec_steps": eng.spec_steps,
        "spec_rounds": eng.spec_rounds,
        "spec_proposed": eng.spec_proposed,
        "spec_accepted": eng.spec_accepted,
        "spec_emitted": eng.spec_emitted,
        "spec_acceptance": eng.spec_accepted / max(eng.spec_proposed, 1),
        "spec_tokens_per_round": (eng.spec_emitted
                                  / max(eng.spec_rounds, 1)),
        **{f"prefix_{k}": v for k, v in eng.prefix_cache.stats().items()},
        "published_frontiers": eng.published_frontiers,
    }
    assert eng.stats() == expected


def test_chrome_trace_valid_and_ttft_exact(traced_pair, tmp_path):
    _, eng = traced_pair
    path = tmp_path / "trace.json"
    meta = eng.dump_chrome_trace(str(path))
    trace = json.loads(path.read_text())
    assert meta["requests"] == 4
    assert validate_chrome_trace(trace["traceEvents"]) == []
    rows = {r["uid"]: r for r in eng.tracer.request_summaries()}
    assert sorted(rows) == [0, 1, 2, 3]
    for row in rows.values():
        parts = (row["queue_wait_us"], row["prefill_us"],
                 row["first_wave_us"], row["ttft_us"])
        assert None not in parts, row
        assert sum(parts[:3]) == pytest.approx(parts[3], abs=1e-6)
        assert row["e2e_us"] is not None and row["e2e_us"] >= parts[3]
        assert row["n_tokens"] == 5
        assert len(row["itl_us"]) == 4
    # speculative rounds are attributed per request, with depth
    # counters aggregated in the registry
    assert any(r["spec_rounds"] for r in rows.values())
    snap = eng.metrics.collect()
    assert snap["spec.depth0.proposed"] >= 1
    # prefix-cache hit-length histogram observed the shared prefix
    assert snap["prefix_cache.hit_tokens_hist"]["count"] >= 1
    # kv_pool traffic counters moved
    assert snap["kv_pool.alloc_blocks"] > 0


def test_dump_requires_trace_enabled(model):
    cfg, params = model
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=2, max_len=96, prefill_buckets=(8, 16, 32)))
    with pytest.raises(ValueError):
        eng.dump_chrome_trace("/tmp/never.json")
