"""Property-fuzz the token-granular radix prefix cache against a plain
dict-of-token-tuples oracle.

The oracle models the cache's DOCUMENTED adoption semantics (see
``RadixPrefixCache.insert``): per namespace it holds the set of chains
whose tokens are matchable, and

* ``match(q)`` must return EXACTLY ``min(longest common prefix of q
  with any chain, max_tokens)`` tokens and ``ceil(n / bs)`` pages;
* ``insert(key)`` adopts the unmatched tail iff the divergence point
  ``m`` lands on a page boundary or exactly extends a resident chain
  that ends mid-page (upgrade) — a mid-page divergence keeps the
  resident chain; adopted inserts return exactly ``m // bs`` duplicate
  pages, refused/duplicate inserts return every page;
* ``evict`` removes leaf chains only (observed through the
  ``on_evict`` callback, which the oracle uses to truncate its
  chains) and never touches pages a reader still holds.

Interleavings also exercise the in-flight publication protocol (incref
then insert a growing page-aligned prefix of a still-owned chain, free
the returned duplicates) — the exact sequence the engine's
``_publish_frontiers`` drives.  After every operation
``pool.assert_consistent()`` must hold, and when every reader and
owner releases at the end, a full evict must return the pool to
all-free (zero leaked pages).
"""
import numpy as np

from repro.serving.kv_pool import KVBlockPool, blocks_for_tokens
from repro.serving.prefix_cache import RadixPrefixCache

from tests._hypothesis_compat import given, settings, st

BS = 4
POOL_BLOCKS = 96
ALPHABET = 3          # tiny vocab => dense prefix collisions
NAMESPACES = (0, 7)


def _common(a, b):
    lim = min(len(a), len(b))
    for i in range(lim):
        if a[i] != b[i]:
            return i
    return lim


class Oracle:
    """Reference model: per-namespace set of matchable chains."""

    def __init__(self):
        self.chains = {ns: set() for ns in NAMESPACES}

    def expect_match(self, ns, q, cap):
        m = max((_common(q, c) for c in self.chains[ns]), default=0)
        return min(m, cap)

    def apply_insert(self, ns, key):
        """Returns expected duplicate-page count for ``insert(key)``."""
        key = tuple(key)
        total = blocks_for_tokens(len(key), BS)
        m = max((_common(key, c) for c in self.chains[ns]), default=0)
        if m == len(key):
            return total                       # fully covered: all dups
        upgrade = any(_common(key, c) == m and len(c) == m
                      for c in self.chains[ns])
        if m % BS == 0 or upgrade:
            if upgrade and m % BS != 0:
                # the tree REPLACES an upgraded partial-tail leaf: the
                # subsumed chain's mid-page endpoint no longer exists,
                # so a later insert reaching depth m mid-page is a
                # refused divergence, not another upgrade
                self.chains[ns] = {c for c in self.chains[ns]
                                   if not (len(c) == m
                                           and _common(key, c) == m)}
            self.chains[ns].add(key)
            return m // BS
        return total                           # mid-page divergence refused

    def apply_evict(self, ns, full_key, n_leaf):
        """Truncate chains that ended inside the evicted leaf edge."""
        cut = len(full_key) - n_leaf
        prefix = tuple(full_key[:cut])
        kept = set()
        for c in self.chains[ns]:
            if _common(c, tuple(full_key)) == len(c) and len(c) > cut:
                if cut:
                    kept.add(prefix)           # ancestors stay indexed
            else:
                kept.add(c)
        self.chains[ns] = kept


def _rand_key(rng, max_len=24):
    n = int(rng.integers(1, max_len + 1))
    return tuple(int(t) for t in rng.integers(0, ALPHABET, n))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_radix_cache_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    pool = KVBlockPool(POOL_BLOCKS, BS)
    evictions = []
    cache = RadixPrefixCache(
        pool, on_evict=lambda ns, k, nl, blks: evictions.append((ns, k, nl)))
    oracle = Oracle()
    readers = []          # block lists held by simulated readers
    owned = []            # in-flight chains: [ns, key, blocks, published]
    hit_stats = []        # (blocks, tokens) of recorded hits, for unrecord

    for _ in range(120):
        ns = NAMESPACES[int(rng.integers(len(NAMESPACES)))]
        op = rng.random()
        if op < 0.30:                                       # match
            q = _rand_key(rng)
            cap = int(rng.integers(1, len(q) + 1))
            blocks, n = cache.match(np.asarray(q, np.int64),
                                    namespace=ns, max_tokens=cap)
            expect = oracle.expect_match(ns, q, cap)
            assert n == expect, (seed, q, cap, n, expect)
            assert len(blocks) == blocks_for_tokens(n, BS)
            if n:
                if rng.random() < 0.5:
                    readers.append(blocks)                  # keep pinned
                else:
                    pool.free(blocks)
                    cache.unrecord_hit(len(blocks), n, (n // BS) * BS)
        elif op < 0.55:                                     # insert finished
            key = _rand_key(rng)
            nb = blocks_for_tokens(len(key), BS)
            if not pool.can_alloc(nb):
                continue
            blocks = pool.alloc(nb)
            expect_dups = oracle.apply_insert(ns, key)
            dups = cache.insert(np.asarray(key, np.int64), blocks,
                                namespace=ns)
            assert len(dups) == expect_dups, (seed, key, dups, expect_dups)
            pool.free(dups)
        elif op < 0.70:                                     # start in-flight
            key = _rand_key(rng)
            nb = blocks_for_tokens(len(key), BS)
            if not pool.can_alloc(nb):
                continue
            owned.append([ns, key, pool.alloc(nb), 0])
        elif op < 0.85 and owned:                           # publish frontier
            ch = owned[int(rng.integers(len(owned)))]
            cns, key, blocks, published = ch
            frontier = min(published + BS, (len(key) // BS) * BS)
            if frontier <= published:
                continue
            pub_blocks = blocks[:frontier // BS]
            pool.share(pub_blocks)
            oracle.apply_insert(cns, key[:frontier])
            dups = cache.insert(np.asarray(key[:frontier], np.int64),
                                pub_blocks, namespace=cns)
            pool.free(dups)
            ch[3] = frontier
        elif op < 0.92 and owned:                           # finish in-flight
            cns, key, blocks, _ = owned.pop(int(rng.integers(len(owned))))
            oracle.apply_insert(cns, key)
            dups = cache.insert(np.asarray(key, np.int64), blocks,
                                namespace=cns)
            pool.free(dups)
        else:                                               # evict
            want = int(rng.integers(1, 9))
            n_before = len(evictions)
            cache.evict(want)
            for ens, ekey, enl in evictions[n_before:]:
                oracle.apply_evict(ens, tuple(int(t) for t in ekey), enl)
        pool.assert_consistent()
        assert cache.hits >= 0 and cache.hit_tokens >= 0
        assert cache.hit_tokens >= cache.hit_tokens_block >= 0

    # drain: every reader and owner releases -> zero leaked pages
    for blocks in readers:
        pool.free(blocks)
    for _, _, blocks, _ in owned:
        pool.free(blocks)
    n_before = len(evictions)
    cache.evict(POOL_BLOCKS)
    for ens, ekey, enl in evictions[n_before:]:
        oracle.apply_evict(ens, tuple(int(t) for t in ekey), enl)
    pool.assert_consistent()
    assert cache.num_blocks == 0
    assert pool.num_free == POOL_BLOCKS, "leaked pages"
    for ns in NAMESPACES:
        q = _rand_key(rng)
        assert cache.match(np.asarray(q, np.int64), namespace=ns,
                           max_tokens=len(q)) == ([], 0)
