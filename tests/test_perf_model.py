"""Performance controller: roofline estimators + historical EWMA."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.perf_model import (
    DEVICE_CATALOGUE,
    HistoricalEstimator,
    TaskCost,
    estimate,
    inference_cost,
    model_flops_per_token,
    training_cost,
)


def test_hub_dominates_phone():
    cost = inference_cost(get_config("phi3-medium-14b"), 1, 128)
    hub = estimate(cost, DEVICE_CATALOGUE["edgeai-hub"])
    phone = estimate(cost, DEVICE_CATALOGUE["mid-phone"])
    assert hub.latency_s < phone.latency_s
    assert not phone.fits_memory        # 28 GB f16 weights vs 6 GB phone
    assert hub.fits_memory or cost.mem_bytes > 16e9


def test_decode_is_memory_bound_on_edge():
    """The paper's TinyBERT point: single-token decode streams weights."""
    for name in ("flagship-phone", "mid-phone", "edgeai-hub"):
        cost = inference_cost(get_config("gemma2-9b"), 1, 1)
        est = estimate(cost, DEVICE_CATALOGUE[name])
        assert est.bottleneck == "memory"


def test_training_far_heavier_than_inference():
    cfg = get_config("gemma3-1b")
    t = training_cost(cfg, 8, 128)
    i = inference_cost(cfg, 8, 128)
    assert t.flops == pytest.approx(3 * i.flops)
    assert t.mem_bytes > i.mem_bytes


def test_moe_flops_use_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.param_count() > 15 * kimi.active_param_count()
    f = model_flops_per_token(kimi)
    assert f == 2.0 * kimi.active_param_count()


@settings(max_examples=30, deadline=None)
@given(st.floats(1e9, 1e15), st.floats(1e6, 1e12))
def test_estimate_roofline_property(flops, mem):
    """latency == max(compute, memory) and DVFS slows compute."""
    dev = DEVICE_CATALOGUE["flagship-phone"]
    cost = TaskCost(flops=flops, weight_bytes=mem, activation_bytes=0.0)
    est = estimate(cost, dev)
    assert est.latency_s == pytest.approx(
        max(est.compute_s, est.memory_s))
    slow = estimate(cost, dev, dvfs=0.5)
    assert slow.compute_s >= est.compute_s


def test_historical_estimator_converges():
    h = HistoricalEstimator(alpha=0.5)
    assert h.predict("t", "d") is None
    for _ in range(10):
        h.observe("t", "d", 2.0)
    assert h.predict("t", "d") == pytest.approx(2.0, rel=0.01)
    h.observe("t", "d", 4.0)
    assert 2.0 < h.predict("t", "d") < 4.0
