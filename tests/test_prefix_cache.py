"""Shared-prefix radix cache: index semantics, engine integration, and
the behaviour-invariance contract.

The tentpole property under test: tokens decoded after a radix-cache
prefix HIT are bit-identical to a cold (cache-off) run — including
under temperature/top-k sampling and across preempt/resume — while the
prefix's prefill is skipped entirely.  Sharable families (fully-paged
state) are exercised for real hits; non-sharable configs must keep the
cache disabled and behave exactly as before.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import (EdgeServingEngine, KVBlockPool, RadixPrefixCache,
                           Request, ServeConfig)


# ---------------------------------------------------------------------------
# radix index semantics (host-side, real pool refcounts)
# ---------------------------------------------------------------------------

BS = 4


def _pool_cache(blocks=32):
    pool = KVBlockPool(blocks, BS)
    return pool, RadixPrefixCache(pool)


def _key(*toks):
    return np.asarray(toks, np.int64)


def test_insert_then_match_shares_pages():
    pool, cache = _pool_cache()
    b = pool.alloc(2)
    assert cache.insert(_key(*range(8)), b) == []      # both pages adopted
    got, n = cache.match(_key(*range(8), 99, 98), max_tokens=9)
    assert got == b and n == 8
    assert all(pool.refcount(x) == 2 for x in b)       # cache + reader
    pool.free(got)                                     # reader releases
    assert all(pool.refcount(x) == 1 for x in b)


def test_match_is_token_granular():
    """A query diverging (or capped) mid-page still matches its true
    token prefix — the partially-matched final page is returned for the
    caller to CoW-fork (the PR-3 matcher rounded down to whole pages)."""
    pool, cache = _pool_cache()
    b = pool.alloc(2)
    cache.insert(_key(*range(8)), b)
    got, n = cache.match(_key(*range(8)), max_tokens=7)
    assert n == 7 and got == b          # capped mid-page, both pages
    pool.free(got)
    got, n = cache.match(_key(*range(5)), max_tokens=4)
    assert n == 4 and got == b[:1]      # cap lands exactly on the boundary
    pool.free(got)
    got, n = cache.match(_key(0, 1, 2, 3, 4, 5, 77, 78), max_tokens=7)
    assert n == 6 and got == b          # divergence inside page 2
    pool.free(got)
    st = cache.stats()
    assert st["hit_tokens"] == 7 + 4 + 6
    assert st["hit_tokens_block"] == 4 + 4 + 4   # what PR-3 would serve
    pool.assert_consistent()


def test_min_match_tokens_admission_floor():
    """Matches shorter than ``min_match_tokens`` are refused: counted
    as misses, no refcounts taken, no LRU stamp — a too-short overlap
    must not pin pages or shadow a colder-but-longer chain."""
    pool = KVBlockPool(32, BS)
    cache = RadixPrefixCache(pool, min_match_tokens=8)
    b = pool.alloc(4)
    cache.insert(_key(*range(16)), b)
    got, n = cache.match(_key(*range(4), 90, 91), max_tokens=6)
    assert got == [] and n == 0                  # 4-token overlap < floor
    assert all(pool.refcount(x) == 1 for x in b)  # cache ref only
    assert cache.stats()["short_matches"] == 1
    got, n = cache.match(_key(*range(16)), max_tokens=16)
    assert n == 16 and got == b                  # at/above floor: real hit
    pool.free(got)
    assert cache.stats()["short_matches"] == 1   # hits don't count
    pool.assert_consistent()


def test_partial_tail_is_indexed_and_upgraded():
    """A chain whose length is not a page multiple retires WITH its
    partial tail page; a longer chain extending it replaces that page
    (the cache releases its superseded reference — no leak)."""
    pool, cache = _pool_cache()
    b = pool.alloc(3)                            # 9 tokens: 2 full + 1 partial
    assert cache.insert(_key(*range(9)), b) == []
    got, n = cache.match(_key(*range(9), 50), max_tokens=9)
    assert n == 9 and got == b
    pool.free(got)
    b2 = pool.alloc(3)                           # 12 tokens, same prefix
    dups = cache.insert(_key(*range(12)), b2)
    assert dups == b2[:2]                        # full-page prefix deduped
    pool.free(dups)
    assert pool.refcount(b[2]) == 0              # superseded partial freed
    got, n = cache.match(_key(*range(12)), max_tokens=20)
    assert n == 12 and got == b[:2] + b2[2:]
    pool.free(got)
    assert cache.replaced_blocks == 1
    pool.assert_consistent()


def test_mid_page_divergent_insert_is_refused():
    """Two chains cannot share a page they disagree on: an insert that
    diverges from the resident chain mid-page keeps the resident and
    returns the whole incoming chain for the caller to free."""
    pool, cache = _pool_cache()
    b = pool.alloc(2)
    cache.insert(_key(*range(8)), b)
    b2 = pool.alloc(2)
    div = _key(0, 1, 2, 3, 4, 5, 77, 78)         # diverges at token 6
    assert cache.insert(div, b2) == b2
    pool.free(b2)
    got, n = cache.match(div, max_tokens=7)
    assert n == 6 and got == b                   # resident chain serves it
    pool.free(got)
    pool.assert_consistent()


def test_insert_duplicate_chain_is_deduped():
    pool, cache = _pool_cache()
    b1 = pool.alloc(2)
    cache.insert(_key(*range(8)), b1)
    b2 = pool.alloc(2)
    # same tokens admitted cold concurrently: second copy is redundant
    assert cache.insert(_key(*range(8)), b2) == b2
    pool.free(b2)
    assert cache.num_blocks == 2
    pool.assert_consistent()


def test_insert_extension_adopts_only_the_tail():
    pool, cache = _pool_cache()
    b1 = pool.alloc(2)
    cache.insert(_key(*range(8)), b1)
    b3 = pool.alloc(3)                  # 12 tokens, first 8 identical
    dups = cache.insert(_key(*range(12)), b3)
    assert dups == b3[:2]               # prefix already indexed
    pool.free(dups)
    got, n = cache.match(_key(*range(12), 5), max_tokens=12)
    assert n == 12 and got == b1 + b3[2:]
    pool.free(got)


def test_insert_divergent_chain_splits_edge():
    pool, cache = _pool_cache()
    b1 = pool.alloc(3)
    cache.insert(_key(*range(12)), b1)
    div = list(range(8)) + [77, 78, 79, 80]     # diverges at block 2
    b2 = pool.alloc(3)
    dups = cache.insert(_key(*div), b2)
    assert dups == b2[:2]
    pool.free(dups)
    got, n = cache.match(_key(*div, 1), max_tokens=12)
    assert n == 12 and got == b1[:2] + b2[2:]
    pool.free(got)
    got, n = cache.match(_key(*range(12), 1), max_tokens=12)
    assert n == 12 and got == b1
    pool.free(got)


def test_unrecord_hit_rolls_back_retry_stats():
    """A reader that releases its chain unused (admission retry under
    pool pressure) must not inflate hit counters: after N acquire/
    release cycles the stats read as if nothing was ever served."""
    pool, cache = _pool_cache()
    b = pool.alloc(2)
    cache.insert(_key(*range(8)), b)
    for _ in range(5):
        got, n = cache.match(_key(*range(8), 1), max_tokens=8)
        assert n == 8
        pool.free(got)
        cache.unrecord_hit(len(got), n, (n // BS) * BS)
    assert cache.hits == 0 and cache.hit_blocks == 0
    assert cache.hit_tokens == 0 and cache.hit_tokens_block == 0
    got, _ = cache.match(_key(*range(8), 1), max_tokens=8)
    assert cache.hits == 1 and cache.hit_blocks == 2
    assert cache.hit_tokens == 8
    pool.free(got)


def test_namespaces_do_not_cross_match():
    pool, cache = _pool_cache()
    b = pool.alloc(2)
    cache.insert(_key(*range(8)), b, namespace=111)
    got, n = cache.match(_key(*range(8)), namespace=222, max_tokens=8)
    assert n == 0 and got == []
    got, n = cache.match(_key(*range(8)), namespace=111, max_tokens=8)
    assert n == 8
    pool.free(got)


def test_evict_lru_skips_pinned_chains():
    pool, cache = _pool_cache(blocks=8)
    b1 = pool.alloc(2)
    cache.insert(_key(1, 1, 1, 1, 2, 2, 2, 2), b1)
    b2 = pool.alloc(2)
    cache.insert(_key(3, 3, 3, 3, 4, 4, 4, 4), b2)
    # touch chain 1 => chain 2 is LRU
    got, _ = cache.match(_key(1, 1, 1, 1, 2, 2, 2, 2), max_tokens=8)
    assert cache.evictable_blocks() == 2       # only the unpinned chain 2
    freed = cache.evict(1)
    assert freed == 2 and pool.refcount(b2[0]) == 0
    # chain 1 pinned by the reader: nothing more to evict
    assert cache.evict(4) == 0
    pool.free(got)
    assert cache.evict(4) == 2                 # now reclaimable
    pool.assert_consistent()
    assert pool.num_free == pool.num_blocks


# ---------------------------------------------------------------------------
# engine integration: hit == cold, per family
# ---------------------------------------------------------------------------

SHARABLE = ["phi3-medium-14b", "granite-moe-1b-a400m", "internvl2-76b",
            "whisper-base"]


def _family_setup(arch):
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=100.0)   # no token dropping
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _extras(cfg, seed=0):
    rng = np.random.default_rng(seed)
    e = {}
    if cfg.family == "encdec":
        e["audio_embeds"] = rng.normal(
            0, 0.1, (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        e["image_embeds"] = rng.normal(
            0, 0.1, (cfg.num_image_tokens, cfg.image_embed_dim)
        ).astype(np.float32)
    return e


def _shared_traffic(cfg, n=4, sys_len=24):
    """n requests sharing a system prompt, unique tails, mixed
    sampling params; same extras (sharing requires identical
    non-token inputs)."""
    rng = np.random.default_rng(7)
    sys_prompt = rng.integers(0, cfg.vocab_size, sys_len, dtype=np.int32)
    ext = _extras(cfg)
    reqs = []
    for uid in range(n):
        r2 = np.random.default_rng(50 + uid)
        tail = r2.integers(0, cfg.vocab_size, 4 + uid, dtype=np.int32)
        reqs.append(Request(
            uid=uid, prompt=np.concatenate([sys_prompt, tail]),
            max_new_tokens=5, extras=dict(ext),
            temperature=0.8 if uid % 2 else 0.0,
            top_k=6 if uid % 2 else 0))
    return reqs


def _run_sequential(cfg, params, reqs, prefix_cache):
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=2, max_len=96, prefill_buckets=(16, 32), seed=5,
        prefix_cache=prefix_cache))
    for r in reqs:
        eng.submit(r)
        eng.run_until_drained()
    return eng, {r.uid: tuple(r.generated) for r in eng.completed}


@pytest.mark.parametrize("arch", SHARABLE)
def test_prefix_hit_decode_bit_identical_to_cold(arch):
    """Sequential same-prefix traffic: later requests HIT the radix
    cache (prefix prefill skipped) yet decode token-for-token exactly
    what a cache-off engine decodes — greedy AND sampled."""
    cfg, params = _family_setup(arch)
    eng_off, cold = _run_sequential(cfg, params, _shared_traffic(cfg), False)
    eng_on, hot = _run_sequential(cfg, params, _shared_traffic(cfg), True)
    assert eng_off.prefix_cache is None
    assert hot == cold
    st = eng_on.prefix_cache.stats()
    assert st["hits"] >= 2, st          # sharing really engaged
    eng_on.pool.assert_consistent()
    # no page leak: every page is free or owned by the cache index
    assert (eng_on.pool.num_free + eng_on.prefix_cache.num_blocks
            == eng_on.pool.num_blocks)


@pytest.mark.parametrize("arch", ["internvl2-76b", "whisper-base"])
def test_different_extras_never_share(arch):
    """Same token ids but different image/audio => KV differs => the
    namespace digest must force a MISS (sharing would corrupt decode)."""
    cfg, params = _family_setup(arch)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 24, dtype=np.int32)
    reqs = [Request(uid=0, prompt=prompt.copy(), max_new_tokens=4,
                    extras=_extras(cfg, seed=0)),
            Request(uid=1, prompt=prompt.copy(), max_new_tokens=4,
                    extras=_extras(cfg, seed=1))]      # different extras
    eng, hot = _run_sequential(cfg, params, reqs, True)
    assert eng.prefix_cache.hits == 0
    _, cold = _run_sequential(
        cfg, params,
        [Request(uid=1, prompt=prompt.copy(), max_new_tokens=4,
                 extras=_extras(cfg, seed=1))], False)
    assert hot[1] == cold[1]


def test_nonsharable_configs_keep_cache_off():
    """Local-ring (gemma pattern) and recurrent (ssm/hybrid) state is
    not reconstructible from pages: the radix cache must stay disabled
    and admission must be the plain cold path."""
    for arch in ("gemma3-1b", "mamba2-370m", "zamba2-7b"):
        cfg, params = _family_setup(arch)
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=2, max_len=64, prefill_buckets=(16,),
            prefix_cache=True))
        assert eng.prefix_cache is None and not eng.sharable
        rng = np.random.default_rng(0)
        eng.submit(Request(uid=0,
                           prompt=rng.integers(0, cfg.vocab_size, 6,
                                               dtype=np.int32),
                           max_new_tokens=3))
        assert len(eng.run_until_drained()) == 1


# ---------------------------------------------------------------------------
# preemption while pages are shared
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["phi3-medium-14b",
                                  "granite-moe-1b-a400m"])
def test_preempt_shared_pages_resumes_bit_identical(arch):
    """Preempt a request whose prefix pages are SHARED with the radix
    cache mid-decode, resume it, and require token-for-token equality
    with both an uninterrupted cache-on run and a cache-off run — plus
    zero page leak afterwards."""
    cfg, params = _family_setup(arch)
    rng = np.random.default_rng(11)
    # lengths chosen so cold and hit admissions have the SAME decode
    # wave schedule (no chunked catch-up): the engine's sampling keys
    # are indexed by wave, so only an aligned schedule can be compared
    # token-for-token under temperature
    sys_prompt = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    tail = rng.integers(0, cfg.vocab_size, 5, dtype=np.int32)

    def fresh(uid):
        return Request(uid=uid, prompt=np.concatenate([sys_prompt, tail]),
                       max_new_tokens=8, temperature=0.9, top_k=8)

    def seed_chain(eng):
        r0 = Request(uid=0, prompt=sys_prompt.copy(), max_new_tokens=2)
        eng.submit(r0)
        eng.run_until_drained()

    scfg = ServeConfig(max_slots=1, max_len=96, prefill_buckets=(8, 32),
                       seed=9, prefix_cache=True)
    # uninterrupted cache-on baseline
    eng0 = EdgeServingEngine(cfg, params, scfg)
    seed_chain(eng0)
    eng0.submit(fresh(1))
    eng0.run_until_drained()
    baseline = tuple(eng0.completed[-1].generated)
    assert eng0.prefix_cache.hits >= 1

    # cache-off baseline (same request sequence => same rng stream):
    # sharing must not change tokens at all
    engc = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=1, max_len=96, prefill_buckets=(8, 32), seed=9,
        prefix_cache=False))
    seed_chain(engc)
    engc.submit(fresh(1))
    engc.run_until_drained()
    assert tuple(engc.completed[-1].generated) == baseline

    # preempt mid-decode while holding shared pages, then resume
    eng = EdgeServingEngine(cfg, params, scfg)
    seed_chain(eng)
    req = fresh(1)
    eng.submit(req)
    eng.step()
    eng.step()
    assert not req.done and len(req.generated) >= 1
    shared = [b for b in eng.slot_blocks[0] if eng.pool.refcount(b) > 1]
    assert shared, "the slot should hold cache-shared prefix pages"
    got = eng.preempt(0)
    eng.pool.assert_consistent()
    eng.submit(got)
    eng.run_until_drained()
    assert tuple(got.generated) == baseline
    assert len(eng._prefills) and got.saved_state is None
    eng.pool.assert_consistent()
    assert (eng.pool.num_free + eng.prefix_cache.num_blocks
            == eng.pool.num_blocks)


# ---------------------------------------------------------------------------
# copy-on-write guard
# ---------------------------------------------------------------------------

def test_cow_guard_forks_shared_tail_page():
    """If the page a slot is about to WRITE gains a second owner, the
    engine must fork it (private copy) before the wave — decode output
    unchanged, refcounts balanced.  Block-granular matching never
    produces this organically; simulate the future sharer directly."""
    cfg, params = _family_setup("phi3-medium-14b")
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)

    def run(poke):
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=1, max_len=64, prefill_buckets=(8,), seed=1))
        eng.submit(Request(uid=0, prompt=prompt.copy(), max_new_tokens=6))
        eng.step()
        stolen = None
        if poke:
            j = int(eng.pos[0]) // eng.block_size
            stolen = eng.slot_blocks[0][j]
            eng.pool.share([stolen])        # simulated second owner
        eng.run_until_drained()
        if stolen is not None:
            assert eng.cow_forks >= 1
            assert eng.pool.refcount(stolen) == 1   # only our fake owner
            eng.pool.free([stolen])
        eng.pool.assert_consistent()
        return tuple(eng.completed[0].generated), eng

    base, _ = run(poke=False)
    forked, eng = run(poke=True)
    assert forked == base               # fork is invisible to decode
    cached = eng.prefix_cache.num_blocks if eng.prefix_cache else 0
    assert eng.pool.num_free + cached == eng.pool.num_blocks


# ---------------------------------------------------------------------------
# pallas paged-decode swap-in
# ---------------------------------------------------------------------------

def test_pallas_paged_decode_matches_gather_tokens():
    """ServeConfig.use_pallas_paged routes the jitted decode through the
    Pallas paged_attention kernel; at f32 the token stream must equal
    the jnp-gather path exactly (at bf16 they differ only by the
    kernel's f32 PV accumulation — checked at the logits level below)."""
    cfg = get_smoke_config("phi3-medium-14b").replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def run(use_pallas):
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=2, max_len=64, prefill_buckets=(8,),
            use_pallas_paged=use_pallas))
        for uid in range(2):
            r2 = np.random.default_rng(uid)
            eng.submit(Request(uid=uid,
                               prompt=r2.integers(0, cfg.vocab_size, 6,
                                                  dtype=np.int32),
                               max_new_tokens=5))
        return {r.uid: tuple(r.generated)
                for r in eng.run_until_drained()}

    assert run(True) == run(False)


def test_pallas_paged_decode_logits_close_bf16():
    """Layer-level check at serving dtype (bf16): one decode_step_paged
    with the kernel vs the gather read, logits allclose to bf16
    accumulation tolerance."""
    cfg = get_smoke_config("phi3-medium-14b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    nB, bs, max_len = 12, 16, 64
    cache = M.init_paged_cache(cfg, 2, max_len, nB, bs)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0,
                              cfg.vocab_size, jnp.int32)
    wt = jnp.asarray([[7, -1, -1, -1], [5, -1, -1, -1]], jnp.int32)
    _, cache = M.prefill_paged(cfg, params, {"tokens": toks}, max_len,
                               cache, slots=jnp.asarray([0, 1], jnp.int32),
                               write_tables=wt,
                               true_len=jnp.asarray([9, 6], jnp.int32))
    nxt = jnp.asarray([[3], [4]], jnp.int32)
    pos = jnp.asarray([9, 6], jnp.int32)
    lg_g, _ = M.decode_step_paged(cfg, params, cache, nxt, pos, wt, False)
    lg_p, _ = M.decode_step_paged(cfg, params, cache, nxt, pos, wt, True)
    np.testing.assert_allclose(np.asarray(lg_p, np.float32),
                               np.asarray(lg_g, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# pool pressure: eviction keeps admission live, invariant every step
# ---------------------------------------------------------------------------

def test_eviction_under_pressure_and_invariant_every_step():
    """A pool sized well below (chains + new traffic): finished chains
    park in the cache, later admissions evict LRU chains for pages.
    Everything drains, output equals the cache-off run, and the pool
    invariant holds at every drain_step (checked internally)."""
    cfg, params = _family_setup("phi3-medium-14b")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, 20 + 3 * i, dtype=np.int32)
               for i in range(6)]

    def run(prefix_cache):
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=2, max_len=64, prefill_buckets=(8, 16, 32),
            kv_block_size=16, kv_pool_blocks=8, seed=0,
            prefix_cache=prefix_cache))
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p.copy(), max_new_tokens=6))
        eng.run_until_drained()
        return eng, {r.uid: tuple(r.generated) for r in eng.completed}

    eng_on, hot = run(True)
    eng_off, cold = run(False)
    assert len(hot) == 6 and hot == cold
    assert eng_on.prefix_cache.evicted_blocks > 0   # pressure really evicted
    eng_on.pool.assert_consistent()
    assert (eng_on.pool.num_free + eng_on.prefix_cache.num_blocks
            == eng_on.pool.num_blocks)


# ---------------------------------------------------------------------------
# token-granular hits: suffix prefill starts mid-page
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", SHARABLE)
def test_token_granular_hit_bit_identical_to_cold(arch):
    """System prompts whose length is NOT a page multiple: the hit ends
    mid-page, admission CoW-forks the partial page and prefills only
    the true token suffix — decode must still equal the cache-off run
    token-for-token, and the matched token count must strictly beat
    the block-granular counterfactual."""
    cfg, params = _family_setup(arch)
    rng = np.random.default_rng(13)
    sys_prompt = rng.integers(0, cfg.vocab_size, 21, dtype=np.int32)

    def traffic():
        reqs = []
        for uid in range(3):
            tail = np.random.default_rng(70 + uid).integers(
                0, cfg.vocab_size, 5 + uid, dtype=np.int32)
            reqs.append(Request(
                uid=uid, prompt=np.concatenate([sys_prompt, tail]),
                max_new_tokens=5, extras=_extras(cfg),
                temperature=0.8 if uid == 2 else 0.0,
                top_k=6 if uid == 2 else 0))
        return reqs

    eng_off, cold = _run_sequential(cfg, params, traffic(), False)
    eng_on, hot = _run_sequential(cfg, params, traffic(), True)
    assert hot == cold
    st = eng_on.prefix_cache.stats()
    assert st["hits"] >= 2, st
    # 21-token shared prefix with 16-token pages: every hit gains the
    # 5 mid-page tokens the block-granular matcher would have dropped
    assert st["hit_tokens"] > st["hit_tokens_block"], st
    assert eng_on.cow_forks >= 1          # the partial page really forked
    eng_on.pool.assert_consistent()
    assert (eng_on.pool.num_free + eng_on.prefix_cache.num_blocks
            == eng_on.pool.num_blocks)


def test_partial_tail_retire_serves_longer_hits():
    """A finished chain retires with its partial tail page indexed:
    a follow-up whose prompt extends past the previous chain's full
    pages must match INTO the tail page (token count above the page
    boundary) and still decode exactly like a cold engine."""
    cfg, params = _family_setup("phi3-medium-14b")
    rng = np.random.default_rng(3)
    base = rng.integers(0, cfg.vocab_size, 19, dtype=np.int32)  # 1 full + 3

    def traffic():
        return [Request(uid=0, prompt=base.copy(), max_new_tokens=4),
                Request(uid=1,
                        prompt=np.concatenate(
                            [base, np.asarray([9, 8, 7], np.int32)]),
                        max_new_tokens=4)]

    _, cold = _run_sequential(cfg, params, traffic(), False)
    eng, hot = _run_sequential(cfg, params, traffic(), True)
    assert hot == cold
    st = eng.prefix_cache.stats()
    assert st["hits"] >= 1
    assert st["hit_tokens"] >= 19         # matched into the partial tail
    assert st["hit_tokens"] > st["hit_tokens_block"]
    eng.pool.assert_consistent()


# ---------------------------------------------------------------------------
# in-flight sharing: hit a chain that is still decoding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", SHARABLE)
def test_in_flight_hit_bit_identical_to_cold(arch):
    """A reader admitted while the writer is STILL decoding must hit
    the writer's published frontier, share its pages below the
    frontier, and decode exactly what a cold engine decodes — while
    the writer's own output stays untouched."""
    cfg, params = _family_setup(arch)
    rng = np.random.default_rng(17)
    sys_prompt = rng.integers(0, cfg.vocab_size, 24, dtype=np.int32)
    tail = np.asarray([3, 1, 4, 1, 5], np.int32)

    def fresh_pair():
        return (Request(uid=0, prompt=sys_prompt.copy(), max_new_tokens=16,
                        extras=_extras(cfg)),
                Request(uid=1, prompt=np.concatenate([sys_prompt, tail]),
                        max_new_tokens=5, extras=_extras(cfg)))

    scfg = ServeConfig(max_slots=2, max_len=96, prefill_buckets=(16, 32),
                       seed=5, prefix_cache=True)
    eng = EdgeServingEngine(cfg, params, scfg)
    writer, reader = fresh_pair()
    eng.submit(writer)
    for _ in range(3):
        eng.drain_step()
    assert not writer.done
    assert eng.published_frontiers >= 1           # frontier really published
    eng.submit(reader)
    eng.drain_step()
    assert eng.prefix_cache.hits >= 1, "reader should hit the live chain"
    assert not writer.done, "hit happened while the writer was decoding"
    eng.run_until_drained()
    eng.pool.assert_consistent()
    assert (eng.pool.num_free + eng.prefix_cache.num_blocks
            == eng.pool.num_blocks)

    # cold references: each request alone on a cache-off engine
    for req, got in ((fresh_pair()[0], writer), (fresh_pair()[1], reader)):
        ref = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=2, max_len=96, prefill_buckets=(16, 32), seed=5,
            prefix_cache=False))
        ref.submit(req)
        ref.run_until_drained()
        assert tuple(req.generated) == tuple(got.generated), (
            req.generated, got.generated)


def test_in_flight_published_pages_survive_writer_rollback():
    """Spec-decode writer + in-flight reader: rejected speculation
    rolls the writer back (tail pages freed) strictly ABOVE the
    published frontier, so the reader's shared view is never touched;
    greedy output equals the vanilla engine for both and the pool
    stays consistent through every drain step."""
    cfg, params = _family_setup("phi3-medium-14b")
    rng = np.random.default_rng(23)
    sys_prompt = rng.integers(0, cfg.vocab_size, 24, dtype=np.int32)
    tail = np.asarray([2, 7, 1, 8], np.int32)

    def fresh_pair():
        return (Request(uid=0, prompt=sys_prompt.copy(), max_new_tokens=14),
                Request(uid=1, prompt=np.concatenate([sys_prompt, tail]),
                        max_new_tokens=5))

    def run(spec):
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=2, max_len=96, prefill_buckets=(16, 32), seed=5,
            prefix_cache=True, spec_decode=spec, draft_arch="self"))
        writer, reader = fresh_pair()
        eng.submit(writer)
        for _ in range(2):
            eng.drain_step()
        eng.submit(reader)
        eng.run_until_drained()
        eng.pool.assert_consistent()
        if spec:
            assert eng.spec_rounds >= 1
            assert eng.prefix_cache.hits >= 1
        return {r.uid: tuple(r.generated) for r in eng.completed}

    assert run(True) == run(False)
