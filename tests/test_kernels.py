"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels run in interpret=True on CPU — the kernel body (BlockSpec
indexing included) executes for real.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.quant_matmul import quantize_weights


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(32, 64, 128), (64, 256, 128),
                                   (128, 128, 512), (8, 512, 256)])
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_sweep(m, k, n, bits, xdtype):
    key = jax.random.PRNGKey(m * n + bits)
    x = jax.random.normal(key, (m, k), xdtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32) * 0.05
    wq, scale = quantize_weights(w, bits)
    out = ops.quant_matmul(x, wq, scale)
    expect = ref.quant_matmul_ref(x, wq, scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=0.05, atol=0.05)


def test_quantize_weights_bounds():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    for bits in (8, 4):
        q, s = quantize_weights(w, bits)
        lim = 2 ** (bits - 1)
        assert int(jnp.max(q)) <= lim - 1 and int(jnp.min(q)) >= -lim
        err = jnp.abs(q * s[None] - w).max()
        assert float(err) <= float(s.max())  # within one quantization step


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,h,kv,hd", [(64, 4, 2, 32), (128, 4, 4, 64),
                                       (32, 8, 1, 128), (256, 2, 2, 64)])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (16, 0.0),
                                            (0, 50.0), (32, 30.0)])
def test_flash_attention_sweep(s, h, kv, hd, window, softcap):
    key = jax.random.PRNGKey(s + h)
    q = jax.random.normal(key, (2, s, h, hd), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (2, s, kv, hd)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, kv, hd)) * 0.5
    out = ops.flash_attention(q, k, v, scale=hd ** -0.5, window=window,
                              softcap=softcap)
    expect = ref.flash_attention_ref(q, k, v, scale=hd ** -0.5,
                                     window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 64, 4, 64), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 64), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 64), dtype)
    out = ops.flash_attention(q, k, v, scale=0.125)
    expect = ref.flash_attention_ref(q, k, v, scale=0.125)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("l,h,p,n,chunk", [(64, 4, 16, 8, 16),
                                           (128, 2, 32, 16, 32),
                                           (48, 4, 16, 8, 16),  # ragged tail
                                           (32, 8, 64, 32, 8)])
def test_ssd_scan_sweep(l, h, p, n, chunk):
    key = jax.random.PRNGKey(l + h)
    x = jax.random.normal(key, (2, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (2, l, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.PRNGKey(3), (2, l, n))
    C = jax.random.normal(jax.random.PRNGKey(4), (2, l, n))
    y, hf = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    yr, hr = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                               rtol=1e-3, atol=1e-3)


def test_ssd_scan_initial_state():
    """Continuation: scan(second half, h0=state(first half)) == full."""
    key = jax.random.PRNGKey(7)
    l, h, p, n = 64, 2, 16, 8
    x = jax.random.normal(key, (1, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (1, l, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.PRNGKey(3), (1, l, n))
    C = jax.random.normal(jax.random.PRNGKey(4), (1, l, n))
    y_full, h_full = ops.ssd_scan(x, dt, A, B, C, chunk=16)
    _, h1 = ops.ssd_scan(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32],
                         chunk=16)
    y2, h2 = ops.ssd_scan(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:],
                          chunk=16, h0=h1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 32:]),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# paged attention (decode read)
# ---------------------------------------------------------------------------

def _paged_case(seed, B, H, kv, hd, nB, bs, n_blk):
    """Random pool + permuted (non-identity) block tables + ragged
    lengths, so physical page order really differs from logical order."""
    rng = np.random.default_rng(seed)
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, H, hd),
                          jnp.float32) * 0.5
    kp = jax.random.normal(jax.random.PRNGKey(seed + 1),
                           (nB, bs, kv, hd)) * 0.5
    vp = jax.random.normal(jax.random.PRNGKey(seed + 2),
                           (nB, bs, kv, hd)) * 0.5
    bt = np.full((B, n_blk), -1, np.int32)
    lengths = np.zeros((B,), np.int32)
    perm = rng.permutation(nB)
    used = 0
    for b in range(B):
        n = int(rng.integers(1, n_blk * bs + 1))
        lengths[b] = n
        k = -(-n // bs)
        bt[b, :k] = perm[used:used + k]
        used += k
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths)


@pytest.mark.parametrize("B,H,kv,hd,nB,bs,n_blk",
                         [(3, 4, 2, 32, 12, 8, 4),
                          (2, 8, 8, 64, 10, 16, 2),
                          (4, 4, 1, 128, 20, 8, 4)])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_attention_matches_ref(B, H, kv, hd, nB, bs, n_blk, softcap):
    q, kp, vp, bt, ln = _paged_case(B * 7 + H, B, H, kv, hd, nB, bs, n_blk)
    out = ops.paged_attention(q, kp, vp, bt, ln, scale=hd ** -0.5,
                              softcap=softcap)
    exp = ref.paged_attention_ref(q, kp, vp, bt, ln, scale=hd ** -0.5,
                                  softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# int8 KV quantization + fused dequant reads
# ---------------------------------------------------------------------------

def _quant_paged_case(seed, B, H, kv, hd, nB, bs, n_blk):
    """`_paged_case` plus the int8 twin of the pool: per-(token, kv-head)
    symmetric scales as written by ``layers.quantize_kv``."""
    from repro.models import layers as L
    q, kp, vp, bt, ln = _paged_case(seed, B, H, kv, hd, nB, bs, n_blk)
    kq, ks = L.quantize_kv(kp)
    vq, vs = L.quantize_kv(vp)
    return q, kq, ks, vq, vs, bt, ln


def test_quantize_kv_roundtrip_bounds():
    """int8 values stay in [-127, 127] and the dequant error of every
    head_dim vector is within one quantization step of its row scale."""
    from repro.models import layers as L
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 8, 2, 64)) * 2.0
    q, s = L.quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == x.shape[:-1]
    assert int(jnp.max(q)) <= 127 and int(jnp.min(q)) >= -127
    err = jnp.abs(L.dequantize_kv(q, s) - x)
    assert float(jnp.max(err - 0.5 * s[..., None])) <= 1e-6


@pytest.mark.parametrize("B,H,kv,hd,nB,bs,n_blk",
                         [(3, 4, 2, 32, 12, 8, 4),
                          (2, 8, 8, 64, 10, 16, 2)])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_attention_quant_matches_ref(B, H, kv, hd, nB, bs, n_blk,
                                           softcap):
    """Fused dequant decode kernel == gather+dequant reference."""
    q, kq, ks, vq, vs, bt, ln = _quant_paged_case(
        B * 11 + H, B, H, kv, hd, nB, bs, n_blk)
    out = ops.paged_attention(q, kq, vq, bt, ln, scale=hd ** -0.5,
                              softcap=softcap, k_scale=ks, v_scale=vs)
    exp = ref.paged_attention_ref(q, kq, vq, bt, ln, scale=hd ** -0.5,
                                  softcap=softcap, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


def test_paged_attention_quant_close_to_f32():
    """Dequantized attention tracks the f32-pool result within int8
    tolerance — the dequant is semantically a KV read, not just
    self-consistent."""
    from repro.models import layers as L
    B, H, kv, hd, nB, bs, n_blk = 3, 4, 2, 64, 12, 8, 4
    q, kp, vp, bt, ln = _paged_case(5, B, H, kv, hd, nB, bs, n_blk)
    kq, ks = L.quantize_kv(kp)
    vq, vs = L.quantize_kv(vp)
    f32 = ref.paged_attention_ref(q, kp, vp, bt, ln, scale=hd ** -0.5)
    q8 = ops.paged_attention(q, kq, vq, bt, ln, scale=hd ** -0.5,
                             k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(q8), np.asarray(f32),
                               rtol=0.05, atol=0.05)


# ---------------------------------------------------------------------------
# paged extend (multi-token catch-up read)
# ---------------------------------------------------------------------------

def _extend_case(seed, B, H, kv, hd, nB, bs, n_blk, S):
    q1, kp, vp, bt, ln = _paged_case(seed, B, H, kv, hd, nB, bs, n_blk)
    qe = jax.random.normal(jax.random.PRNGKey(seed + 3),
                           (B, S, H, hd), jnp.float32) * 0.5
    kn = jax.random.normal(jax.random.PRNGKey(seed + 4),
                           (B, S, kv, hd)) * 0.5
    vn = jax.random.normal(jax.random.PRNGKey(seed + 5),
                           (B, S, kv, hd)) * 0.5
    return qe, kp, vp, kn, vn, bt, ln


@pytest.mark.parametrize("B,H,kv,hd,nB,bs,n_blk,S",
                         [(3, 4, 2, 32, 12, 8, 4, 4),
                          (2, 8, 8, 64, 10, 16, 2, 6),
                          (4, 4, 1, 128, 20, 8, 4, 3)])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_extend_matches_ref(B, H, kv, hd, nB, bs, n_blk, S, softcap):
    """Fused extend kernel (paged context + dense causal suffix in one
    online-softmax pass) == the gather+concat reference."""
    qe, kp, vp, kn, vn, bt, ln = _extend_case(
        B * 13 + H + S, B, H, kv, hd, nB, bs, n_blk, S)
    out = ops.paged_extend_attention(qe, kp, vp, kn, vn, bt, ln,
                                     scale=hd ** -0.5, softcap=softcap)
    exp = ref.paged_extend_attention_ref(qe, kp, vp, kn, vn, bt, ln,
                                         scale=hd ** -0.5, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_extend_quant_matches_ref(softcap):
    """Fused dequant extend kernel == gather+dequant+concat reference."""
    from repro.models import layers as L
    B, H, kv, hd, nB, bs, n_blk, S = 3, 4, 2, 64, 12, 8, 4, 5
    qe, kp, vp, kn, vn, bt, ln = _extend_case(
        9, B, H, kv, hd, nB, bs, n_blk, S)
    kq, ks = L.quantize_kv(kp)
    vq, vs = L.quantize_kv(vp)
    out = ops.paged_extend_attention(qe, kq, vq, kn, vn, bt, ln,
                                     scale=hd ** -0.5, softcap=softcap,
                                     k_scale=ks, v_scale=vs)
    exp = ref.paged_extend_attention_ref(qe, kq, vq, kn, vn, bt, ln,
                                         scale=hd ** -0.5, softcap=softcap,
                                         k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


def test_paged_ref_matches_contiguous_attention():
    """The gather-based paged reference on an IDENTITY table equals
    masked dense attention over the same contiguous K/V — ties the
    paged oracle back to the existing flash oracle."""
    B, S, H, kv, hd, bs = 2, 32, 4, 2, 32, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, kv, hd)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, kv, hd)) * 0.5
    # chop row 0's K/V into pages 0..3, row 1's into 4..7
    kp = k.reshape(B * S // bs, bs, kv, hd)
    vp = v.reshape(B * S // bs, bs, kv, hd)
    bt = jnp.asarray(np.arange(B * S // bs, dtype=np.int32).reshape(B, -1))
    ln = jnp.full((B,), S, jnp.int32)
    out = ref.paged_attention_ref(q[:, 0], kp, vp, bt, ln, scale=hd ** -0.5)
    # independent oracle: explicit full softmax over the contiguous K/V
    qg = q[:, 0].reshape(B, kv, H // kv, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * hd ** -0.5
    p = jax.nn.softmax(s, axis=-1)
    expect = jnp.einsum("bkgt,btkd->bkgd", p,
                        v.astype(jnp.float32)).reshape(B, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)
