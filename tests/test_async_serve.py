"""Always-on async frontend: streaming, mid-flight arrival/cancel,
graceful shutdown.

Contract under test (``launch.serve.AsyncServingFrontend``): requests
arrive into a live step loop with no drain assumption; every generated
token streams to the request's handle (and optional callback) in
order; ``cancel`` tears a request down mid-flight with zero leaked
pages; ``shutdown`` flushes the prefix-persist store so a restarted
engine rehydrates warm chains.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import AsyncServingFrontend
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig

ARCH = "phi3-medium-14b"        # sharable + spec-decodable smoke arch


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config(ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _scfg(**kw):
    base = dict(max_slots=2, max_len=96, prefill_buckets=(8, 16), seed=11)
    base.update(kw)
    return ServeConfig(**base)


def _req(uid, n=6, **kw):
    rng = np.random.default_rng(100 + uid)
    kw.setdefault("max_new_tokens", 5)
    return Request(uid=uid, prompt=rng.integers(0, 64, n, dtype=np.int32),
                   **kw)


def _assert_no_leak(eng):
    cached = eng.prefix_cache.num_blocks if eng.prefix_cache else 0
    assert eng.pool.num_free + cached == eng.pool.num_blocks
    eng.pool.assert_consistent()


def test_streamed_tokens_match_generated(setup):
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, _scfg())

    async def run():
        fe = AsyncServingFrontend(eng)
        await fe.start()
        handles = [fe.submit(_req(uid)) for uid in range(4)]
        streams = {}

        async def collect(h):
            streams[h.uid] = [tok async for tok in h]
        await asyncio.gather(*(collect(h) for h in handles))
        done = [await h.done for h in handles]
        await fe.shutdown()
        return done, streams

    done, streams = asyncio.run(run())
    assert len(done) == 4
    for r in done:
        assert not r.cancelled and len(r.generated) == 5
        assert streams[r.uid] == [int(t) for t in r.generated]
    _assert_no_leak(eng)


def test_mid_flight_arrival_and_callback(setup):
    """A request submitted while another is decoding joins the live
    batch; the per-token callback fires once per token, in order."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, _scfg(chunked_prefill=True))
    seen = []

    async def run():
        fe = AsyncServingFrontend(eng)
        await fe.start()
        h0 = fe.submit(_req(0, max_new_tokens=10))
        # wait for first token, then land a second request mid-decode
        first = await h0.tokens.get()
        assert first is not None
        h1 = fe.submit(_req(1, max_new_tokens=3),
                       on_token=lambda req, tok: seen.append((req.uid, tok)))
        r1 = await h1.done
        r0 = await h0.done
        await fe.shutdown()
        return r0, r1

    r0, r1 = asyncio.run(run())
    assert len(r0.generated) == 10 and len(r1.generated) == 3
    assert seen == [(1, int(t)) for t in r1.generated]
    assert eng.stats()["wave_admitted"] >= 1
    _assert_no_leak(eng)


def test_cancel_mid_flight_stops_stream(setup):
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, _scfg())

    async def run():
        fe = AsyncServingFrontend(eng)
        await fe.start()
        h = fe.submit(_req(0, max_new_tokens=64))
        await h.tokens.get()                    # it is decoding
        ok = await fe.cancel(h.uid)
        r = await h.done
        # stream must terminate (None sentinel) without hanging
        toks = [t async for t in h]
        unknown = await fe.cancel(999)
        await fe.shutdown()
        return ok, r, toks, unknown

    ok, r, toks, unknown = asyncio.run(run())
    assert ok and r.cancelled and r.done
    assert unknown is False
    assert len(r.generated) < 64
    _assert_no_leak(eng)


def test_shutdown_nodrain_cancels_outstanding(setup):
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, _scfg())

    async def run():
        fe = AsyncServingFrontend(eng)
        await fe.start()
        hs = [fe.submit(_req(uid, max_new_tokens=64)) for uid in range(3)]
        await hs[0].tokens.get()
        await fe.shutdown(drain=False)
        return [await h.done for h in hs]

    done = asyncio.run(run())
    assert all(r.done for r in done)
    assert any(r.cancelled for r in done)
    assert eng.stats()["cancels"] >= 1
    _assert_no_leak(eng)


def test_shutdown_flushes_persist_store(setup, tmp_path):
    """Graceful shutdown writes hot chains; a restarted engine
    rehydrates them warm."""
    cfg, params = setup
    path = str(tmp_path / "hub_store.npz")
    sys_prompt = np.arange(1, 17, dtype=np.int32)   # page-aligned prefix

    def reqs():
        out = []
        for uid in range(3):
            rng = np.random.default_rng(uid)
            tail = rng.integers(0, 64, 4, dtype=np.int32)
            out.append(Request(uid=uid,
                               prompt=np.concatenate([sys_prompt, tail]),
                               max_new_tokens=4))
        return out

    async def serve(eng):
        fe = AsyncServingFrontend(eng)
        await fe.start()
        hs = [fe.submit(r) for r in reqs()]
        for h in hs:
            await h.done
        return await fe.shutdown()

    eng1 = EdgeServingEngine(cfg, params, _scfg(prefix_persist_path=path))
    stats = asyncio.run(serve(eng1))
    assert stats["persist_saved_chains"] >= 1

    eng2 = EdgeServingEngine(cfg, params, _scfg(prefix_persist_path=path))
    st = eng2.stats()
    assert st["persist_loaded_chains"] >= 1
    asyncio.run(serve(eng2))
    assert eng2.stats()["prefix_hits"] >= 1     # restart-warm hit
