"""Mid-flight cancellation: zero leaked pages in every engine phase.

Contract under test (``EdgeServingEngine.cancel``): a request can be
aborted while queued, preempted-and-detached, mid-catch-up, mid-spec
round, or after its frontier pages were published into the radix
cache.  In every case the pool stays consistent with zero leaked
pages, the request lands in ``engine.cancelled`` (never ``completed``),
and already-published chain pages stay readable — a later
same-prefix request still hits.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig

ARCH = "phi3-medium-14b"        # sharable + spec-decodable smoke arch


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config(ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _scfg(**kw):
    base = dict(max_slots=2, max_len=96, prefill_buckets=(8, 16), seed=13)
    base.update(kw)
    return ServeConfig(**base)


def _req(uid, n=6, **kw):
    rng = np.random.default_rng(200 + uid)
    kw.setdefault("max_new_tokens", 8)
    return Request(uid=uid, prompt=rng.integers(0, 64, n, dtype=np.int32),
                   **kw)


def _assert_no_leak(eng):
    cached = eng.prefix_cache.num_blocks if eng.prefix_cache else 0
    assert eng.pool.num_free + cached == eng.pool.num_blocks
    eng.pool.assert_consistent()


def _drain(eng):
    while eng.queue or eng.active.any():
        eng.step()


def test_cancel_during_catchup(setup):
    """Abort a chunk-admitted request while its prompt is still being
    consumed wave by wave (pending tokens outstanding)."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params,
                            _scfg(chunked_prefill=True, catch_chunk=4))
    eng.submit(_req(0, n=40, max_new_tokens=8))
    eng.step()
    slot = next(s for s in range(eng.scfg.max_slots)
                if eng.slot_req[s] is not None)
    assert eng.pending[slot] is not None     # mid-catch-up
    assert eng.cancel(0)
    assert not eng.active.any()
    _assert_no_leak(eng)
    r = eng.cancelled[0]
    assert r.cancelled and r.done and r not in eng.completed
    # the engine keeps serving after the abort
    eng.submit(_req(1, max_new_tokens=3))
    _drain(eng)
    assert len(eng.completed) == 1
    _assert_no_leak(eng)


def test_cancel_during_spec_round(setup):
    """Abort between speculation rounds; the stale draft row needs no
    cleanup and the verifier chain retires without a leak."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params,
                            _scfg(spec_decode=True, draft_arch="self",
                                  spec_gamma=4))
    eng.submit(_req(0, max_new_tokens=48))
    while eng.stats()["spec_rounds"] < 2:
        eng.step()
    assert eng.cancel(0)
    _assert_no_leak(eng)
    assert eng.cancelled[0].cancelled
    eng.submit(_req(1, max_new_tokens=4))
    _drain(eng)
    assert eng.stats()["spec_rounds"] >= 2
    _assert_no_leak(eng)


def test_cancel_with_published_frontier_keeps_chain_readable(setup):
    """Pages published into the radix cache mid-decode survive the
    producer's cancellation: a later request with the same prefix
    still hits the shared chain."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, _scfg())
    bs = eng.block_size
    sys_prompt = np.arange(1, 2 * bs + 1, dtype=np.int32)  # 2 full pages
    eng.submit(Request(uid=0, prompt=sys_prompt.copy(), max_new_tokens=48))
    while eng.slot_published[0] < 2 * bs:    # frontier published
        eng.step()
    hits_before = eng.stats()["prefix_hits"]
    assert eng.cancel(0)
    _assert_no_leak(eng)
    tail = np.array([7, 9, 11], dtype=np.int32)
    eng.submit(Request(uid=1, prompt=np.concatenate([sys_prompt, tail]),
                       max_new_tokens=4))
    _drain(eng)
    assert eng.stats()["prefix_hits"] > hits_before
    _assert_no_leak(eng)


def test_cancel_queued_request(setup):
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, _scfg(max_slots=1))
    eng.submit(_req(0, max_new_tokens=12))
    eng.submit(_req(1, max_new_tokens=12))   # waits in queue
    eng.step()
    assert eng.cancel(1)                     # still queued
    assert not eng.cancel(999)               # unknown uid
    _drain(eng)
    assert {r.uid for r in eng.completed} == {0}
    assert {r.uid for r in eng.cancelled} == {1}
    assert eng.stats()["cancels"] == 1
    _assert_no_leak(eng)


def test_cancel_preempted_request_frees_detached_pages(setup):
    """A preempted request carries its KV pages detached in
    ``saved_state``; cancelling it from the queue frees them."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, _scfg(max_slots=1))
    eng.submit(_req(0, max_new_tokens=24))
    for _ in range(3):
        eng.step()
    req = eng.preempt(0)
    assert req is not None and req.saved_state is not None
    eng.submit(req)                          # back in queue, detached
    assert eng.cancel(0)
    assert req.saved_state is None
    _assert_no_leak(eng)
