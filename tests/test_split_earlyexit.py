"""Split computing + early exit (the paper's offloading & sustainability
mechanisms) — execution correctness and decision sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config, get_smoke_config
from repro.core import earlyexit as EE
from repro.core import split as SP
from repro.core.network import CHANNEL_CATALOGUE, MultiChannelLink
from repro.core.perf_model import DEVICE_CATALOGUE
from repro.models import model as M
from repro.models import transformer as T

CFG = get_smoke_config("phi3-medium-14b")


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              CFG.vocab_size)
    return params, toks


@pytest.mark.parametrize("split", [0, 1, 2])
def test_split_forward_equivalence(setup, split):
    params, toks = setup
    full = T.forward(CFG, params, toks)
    out, payload = SP.split_forward(CFG, params, toks, split, bits=8)
    scale = float(jnp.abs(full).max()) + 1.0
    assert float(jnp.abs(out - full).max()) / scale < 0.05
    if 0 < split < CFG.num_layers:
        assert payload > 0
    else:
        assert payload == 0


def test_higher_bits_less_error(setup):
    params, toks = setup
    full = T.forward(CFG, params, toks)
    errs = []
    for bits in (4, 8):
        out, _ = SP.split_forward(CFG, params, toks, 1, bits=bits)
        errs.append(float(jnp.abs(out - full).max()))
    assert errs[1] < errs[0]


@settings(max_examples=20, deadline=None)
@given(st.floats(-50, 50), st.integers(2, 8))
def test_activation_quant_roundtrip(scale, bits):
    x = scale * jax.random.normal(jax.random.PRNGKey(0), (4, 8, 16))
    q, s = SP.quantize_activations(x, bits)
    back = SP.dequantize_activations(q, s, jnp.float32)
    step = float(jnp.max(jnp.abs(x), axis=-1).max()) / (2 ** (bits - 1) - 1)
    assert float(jnp.abs(back - x).max()) <= step * 0.51 + 1e-6


def test_choose_split_slow_link_avoids_activation_transfer():
    """On a near-dead channel the optimum is an ENDPOINT: shipping an
    int8 activation tensor mid-network (~655 KB here, ~26 s on zigbee)
    can never beat raw tokens up (k=0) or predictions back (k=L).
    LM token payloads are tiny, so full offload may still win — the
    split sweet spot needs payload-heavy inputs or better channels."""
    cfg = get_config("phi3-medium-14b")
    phone = DEVICE_CATALOGUE["flagship-phone"]
    hub = DEVICE_CATALOGUE["edgeai-hub"]
    slow = MultiChannelLink([CHANNEL_CATALOGUE["zigbee"]])
    fast = MultiChannelLink([CHANNEL_CATALOGUE["ethernet"]])
    d_slow = SP.choose_split(cfg, phone, hub, slow, 1, 128)
    d_fast = SP.choose_split(cfg, phone, hub, fast, 1, 128)
    assert d_slow.split in (0, cfg.num_layers)  # endpoint only
    assert d_fast.total_s < d_slow.total_s      # better channel helps
    # and a weak device + fast link prefers offloading the tail
    weak = DEVICE_CATALOGUE["iot-sensor"]
    d_weak = SP.choose_split(cfg, weak, hub, fast, 1, 128)
    assert d_weak.split < cfg.num_layers


def test_choose_split_covers_all_cuts():
    cfg = get_config("gemma2-9b").replace(pattern_period=1)
    phone = DEVICE_CATALOGUE["mid-phone"]
    hub = DEVICE_CATALOGUE["edgeai-hub"]
    link = MultiChannelLink([CHANNEL_CATALOGUE["wifi-legacy"]])
    d = SP.choose_split(cfg, phone, hub, link, 1, 512)
    assert 0 <= d.split <= cfg.num_layers
    assert d.total_s > 0


# ---------------------------------------------------------------------------
# early exit
# ---------------------------------------------------------------------------

def test_exit_heads_training_loss(setup):
    params, toks = setup
    heads = EE.init_exit_heads(CFG, jax.random.PRNGKey(2), [0])
    loss = EE.exit_loss(CFG, params, heads, {"tokens": toks,
                                             "targets": toks})
    assert float(loss) > 0 and not bool(jnp.isnan(loss))
    # grad over the float head params only (exit_layers are static ints)
    grads = jax.grad(lambda ex: EE.exit_loss(
        CFG, params, {"exits": ex, "exit_layers": heads["exit_layers"]},
        {"tokens": toks, "targets": toks}))(heads["exits"])
    assert all(not bool(jnp.isnan(g).any())
               for g in jax.tree.leaves(grads))


def test_low_threshold_exits_early(setup):
    params, toks = setup
    heads = EE.init_exit_heads(CFG, jax.random.PRNGKey(2), [0])
    eager = EE.serve_early_exit(CFG, params, heads, toks, threshold=0.0)
    never = EE.serve_early_exit(CFG, params, heads, toks, threshold=1.1)
    assert eager.expected_layers <= never.expected_layers
    assert eager.flops_saved_frac > 0
    assert never.flops_saved_frac == 0
    assert eager.predictions.shape == toks.shape
