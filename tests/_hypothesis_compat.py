"""Deterministic stand-in for ``hypothesis`` when it is not installed.

Test modules import ``given``/``settings``/``st`` from here instead of
from ``hypothesis`` directly.  With hypothesis available the real thing
is re-exported unchanged; without it, a tiny fixed-seed sampler with the
same decorator surface runs each property against ``max_examples``
pseudo-random examples.  The fallback seed is derived from the test
function's name, so failures reproduce exactly across runs and machines
(no shrinking — offline determinism is the point, not minimality).

Supported strategy surface (everything this suite uses):
``st.integers``, ``st.floats``, ``st.booleans``, ``st.sampled_from``,
``st.lists``, ``st.tuples``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            lo, hi = float(min_value), float(max_value)

            def draw(rng):
                # hit the endpoints occasionally — they are the usual
                # property-breaking values
                r = rng.random()
                if r < 0.05:
                    return lo
                if r < 0.10:
                    return hi
                return float(lo + (hi - lo) * rng.random())
            return _Strategy(draw)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    st = _St()

    def given(*strategies, **kw_strategies):
        def decorate(fn):
            # NOT functools.wraps: pytest would follow __wrapped__ to the
            # original signature and demand fixtures named after the
            # strategy parameters.  The wrapper must look zero-arg.
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode("utf-8"))
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    ex_args = tuple(s.draw(rng) for s in strategies)
                    ex_kwargs = {k: s.draw(rng)
                                 for k, s in kw_strategies.items()}
                    fn(*args, *ex_args, **{**kwargs, **ex_kwargs})
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.hypothesis_fallback = True
            return wrapper
        return decorate

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Accepts (and ignores) deadline/suppress_* kwargs."""
        def decorate(fn):
            fn._max_examples = max_examples
            return fn
        return decorate
