"""Training substrate: optimizer, 8-bit moments, checkpoint, data, loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import InputShape, get_smoke_config
from repro.data import DataConfig, data_iterator, synthetic_tokens
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.trainer import TrainConfig, train_loop


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    cfg = opt.OptimizerConfig(learning_rate=0.1, warmup_steps=1,
                              total_steps=200, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = opt.init_opt_state(cfg, params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_lr_schedule_shape():
    cfg = opt.OptimizerConfig(learning_rate=1.0, warmup_steps=10,
                              total_steps=100, min_lr_ratio=0.1)
    lrs = [float(opt.lr_schedule(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0            # warmup
    assert lrs[10] == pytest.approx(1.0, abs=0.01)
    assert lrs[100] == pytest.approx(0.1, abs=0.02)  # decays to floor


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100), min_size=1, max_size=600))
def test_q8_roundtrip_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    enc = opt._q8_encode(x)
    dec = opt._q8_decode(enc, x.shape, x.size)
    # block-wise error <= half a quantization step of the block max
    step = float(jnp.max(jnp.abs(x))) / 127.0 + 1e-9
    assert float(jnp.abs(dec - x).max()) <= step * 1.01
    assert dec.shape == x.shape


def test_int8_moments_train_real_model():
    """Regression: sqrt-domain int8 v — linear-quantized v diverges on a
    real LM (EXPERIMENTS.md §Perf Hillclimb 3 coda)."""
    cfg = get_smoke_config("gemma3-1b")
    shape = InputShape("t", 64, 8, "train")
    tcfg = TrainConfig(optimizer=opt.OptimizerConfig(
        learning_rate=1e-3, warmup_steps=5, total_steps=40,
        moments_dtype="int8"), remat=None)
    it = data_iterator(cfg, shape, DataConfig(branching=2))
    _, hist = train_loop(cfg, tcfg, it, 30, log_every=5)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8
    assert hist[-1]["loss"] < 10.0  # linear-v int8 blows past 100 here


def test_int8_moments_track_float32():
    """8-bit Adam converges on the same toy problem."""
    params = {"w": jnp.full((512,), 4.0)}
    out = {}
    for dt in ("float32", "int8"):
        cfg = opt.OptimizerConfig(learning_rate=0.1, warmup_steps=1,
                                  total_steps=300, weight_decay=0.0,
                                  moments_dtype=dt)
        p, s = dict(params), opt.init_opt_state(cfg, params)
        for _ in range(100):
            p, s, _ = opt.adamw_update(cfg, {"w": 2 * p["w"]}, s, p)
        out[dt] = float(jnp.abs(p["w"]).max())
    assert out["int8"] < 0.5
    assert abs(out["int8"] - out["float32"]) < 0.3


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    a = synthetic_tokens(DataConfig(seed=3), 128, 4, 16, step=7)
    b = synthetic_tokens(DataConfig(seed=3), 128, 4, 16, step=7)
    np.testing.assert_array_equal(a, b)
    s0 = synthetic_tokens(DataConfig(seed=3, shard_index=0, num_shards=2),
                          128, 2, 16, step=7)
    s1 = synthetic_tokens(DataConfig(seed=3, shard_index=1, num_shards=2),
                          128, 2, 16, step=7)
    assert not np.array_equal(s0, s1)


def test_bigram_chain_is_learnable_structure():
    toks = synthetic_tokens(DataConfig(seed=0, branching=2), 64, 8, 200, 0)
    # successor sets are limited to `branching` per token
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    assert max(len(v) for v in succ.values()) <= 2


def test_train_loop_reduces_loss():
    cfg = get_smoke_config("gemma3-1b")
    shape = InputShape("t", 64, 8, "train")
    tcfg = TrainConfig(optimizer=opt.OptimizerConfig(
        learning_rate=3e-3, warmup_steps=5, total_steps=60), remat=None)
    it = data_iterator(cfg, shape, DataConfig(branching=2))
    _, hist = train_loop(cfg, tcfg, it, 25, log_every=5)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7


def test_grad_accumulation_matches_single_batch():
    cfg = get_smoke_config("phi3-medium-14b")
    shape = InputShape("t", 16, 8, "train")
    key = jax.random.PRNGKey(0)
    batch = M.make_batch(cfg, shape, key)
    from repro.training import trainer as tr
    base = tr.TrainConfig(remat=None, microbatches=1)
    acc = tr.TrainConfig(remat=None, microbatches=4)
    s1 = tr.init_train_state(cfg, base, key)
    s2 = jax.tree.map(lambda x: x, s1)
    s1, m1 = tr.make_train_step(cfg, base)(s1, batch)
    s2, m2 = tr.make_train_step(cfg, acc)(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-3)
    a = jax.tree.leaves(s1["params"])[3]
    b = jax.tree.leaves(s2["params"])[3]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-3)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("granite-moe-1b-a400m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, params, {"note": "test"})
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = ckpt.restore(path, zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(path, {"w": jnp.ones((5,))})
