"""Paged KV-cache: block pool unit tests + engine capacity semantics.

The pool tests are pure host-side allocator checks; the engine tests
assert the tentpole property — the memory ceiling is tokens in flight,
not ``max_slots x max_len`` strips — and that exhaustion degrades into
preempt-or-queue instead of deadlock or divergence.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import (EdgeServingEngine, KVBlockPool, PoolExhausted,
                           Request, ServeConfig, blocks_for_tokens)


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_alloc_free_roundtrip():
    pool = KVBlockPool(8, 16)
    a = pool.alloc(3)
    assert len(a) == len(set(a)) == 3
    assert pool.num_free == 5 and pool.num_used == 3
    pool.free(a)
    assert pool.num_free == 8 and pool.num_used == 0


def test_alloc_exhaustion_is_atomic():
    pool = KVBlockPool(4, 16)
    pool.alloc(3)
    with pytest.raises(PoolExhausted):
        pool.alloc(2)           # only 1 free
    assert pool.num_free == 1   # failed alloc takes nothing
    assert len(pool.alloc(1)) == 1


def test_refcount_shared_pages():
    pool = KVBlockPool(4, 16)
    (b,) = pool.alloc(1)
    pool.incref([b])
    assert pool.refcount(b) == 2
    pool.free([b])
    assert pool.num_free == 3   # still held by the second owner
    pool.free([b])
    assert pool.num_free == 4
    with pytest.raises(ValueError):
        pool.free([b])          # double free
    with pytest.raises(ValueError):
        pool.incref([b])        # incref on unallocated


def test_fragmentation_free_reuse():
    """Interleaved alloc/free can never strand capacity: whatever the
    churn pattern, a full-pool allocation still succeeds afterwards."""
    pool = KVBlockPool(6, 16)
    rng = np.random.default_rng(0)
    held = []
    for _ in range(200):
        if held and (pool.num_free == 0 or rng.random() < 0.5):
            pool.free([held.pop(rng.integers(len(held)))])
        else:
            held.extend(pool.alloc(1))
    pool.free(held)
    assert sorted(pool.alloc(6)) == list(range(6))  # every page usable


def test_blocks_for_tokens():
    assert blocks_for_tokens(0, 16) == 0
    assert blocks_for_tokens(1, 16) == 1
    assert blocks_for_tokens(16, 16) == 1
    assert blocks_for_tokens(17, 16) == 2


def test_share_and_fork_cow_semantics():
    """share() adds an owner; fork() trades the caller's reference on a
    SHARED page for a fresh private page, and is a no-op (same id, no
    alloc) when the caller already owns the page exclusively."""
    pool = KVBlockPool(4, 16)
    (b,) = pool.alloc(1)
    assert pool.fork(b) == b            # sole owner: nothing to do
    pool.share([b])                     # second owner appears
    nb = pool.fork(b)
    assert nb != b
    assert pool.refcount(b) == 1 and pool.refcount(nb) == 1
    pool.assert_consistent()
    pool.free([b, nb])
    assert pool.num_free == 4
    with pytest.raises(ValueError):
        pool.fork(b)                    # unallocated


def test_fork_exhaustion_is_atomic():
    pool = KVBlockPool(2, 16)
    a, b = pool.alloc(2)
    pool.share([a])
    with pytest.raises(PoolExhausted):
        pool.fork(a)                    # no free page for the copy
    assert pool.refcount(a) == 2        # caller's reference untouched
    pool.assert_consistent()


def test_assert_consistent_catches_drift():
    pool = KVBlockPool(4, 16)
    pool.alloc(2)
    pool.assert_consistent()
    pool._refcount[3] = 1               # corrupt: free page with a ref
    with pytest.raises(RuntimeError, match="drift|live refcount"):
        pool.assert_consistent()


def test_randomized_alloc_share_fork_free_interleavings():
    """Property-style stress: any interleaving of alloc/share/fork/free
    keeps the accounting invariant, and when every logical owner
    releases, the pool is exactly full again."""
    pool = KVBlockPool(12, 16)
    rng = np.random.default_rng(42)
    held = []                           # one entry per owned reference
    for step in range(2000):
        ops = ["alloc", "free", "share", "fork"]
        op = ops[rng.integers(len(ops))]
        if op == "alloc" and pool.num_free:
            held.extend(pool.alloc(int(rng.integers(
                1, pool.num_free + 1))))
        elif op == "free" and held:
            pool.free([held.pop(rng.integers(len(held)))])
        elif op == "share" and held:
            b = held[rng.integers(len(held))]
            pool.share([b])
            held.append(b)
        elif op == "fork" and held and pool.num_free:
            i = rng.integers(len(held))
            held[i] = pool.fork(held[i])
        pool.assert_consistent()
        owned = len(set(held))
        assert pool.num_used == owned, (step, op)
        assert sorted(np.nonzero(pool._refcount)[0]) == sorted(set(held))
    pool.free(held)
    pool.assert_consistent()
    assert pool.num_free == pool.num_blocks


# ---------------------------------------------------------------------------
# engine capacity semantics
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _req(uid, n=5, **kw):
    rng = np.random.default_rng(uid)
    return Request(uid=uid, prompt=rng.integers(0, 64, n, dtype=np.int32),
                   **kw)


def test_paged_admits_more_than_dense_budget(setup):
    """Same KV-byte budget, block_size=16: a dense engine fits exactly
    2 max_len strips (8 blocks / 4 per strip); the paged engine runs 6
    short requests CONCURRENTLY on those same bytes."""
    cfg, params = setup
    dense_slots = 2
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=6, max_len=64, prefill_buckets=(8,),
        kv_block_size=16, kv_pool_blocks=dense_slots * (64 // 16)))
    for uid in range(6):
        eng.submit(_req(uid, max_new_tokens=4))
    done = eng.run_until_drained()
    assert len(done) == 6
    assert all(len(r.generated) == 4 for r in done)
    assert eng.peak_active == 6 > dense_slots
    assert eng.exhaust_preempts == 0        # no pressure at this length


def test_pool_pressure_preempts_not_deadlocks(setup):
    """4 tenants whose pages overflow a 5-page pool: boundary crossings
    exhaust the pool; the engine must preempt-or-queue (pages detached)
    and still drain with output identical to an unpressured run.
    Staggered lengths keep finishes freeing pages in time, so only the
    bit-exact detach/resume path fires (reclaims == 0 asserts that —
    forced reclaim re-prefills and is only approximately identical, see
    test_forced_reclaim_drains)."""
    cfg, params = setup

    def run(pool_blocks):
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=4, max_len=64, prefill_buckets=(8,),
            kv_block_size=16, kv_pool_blocks=pool_blocks))
        for uid in range(4):
            eng.submit(_req(uid, n=6, max_new_tokens=12 + 6 * uid))
        done = eng.run_until_drained()
        return eng, {r.uid: tuple(r.generated) for r in done}

    ample_eng, ample = run(16)
    tight_eng, tight = run(5)
    assert ample_eng.exhaust_preempts == 0
    assert tight_eng.exhaust_preempts > 0   # pressure really happened
    assert tight_eng.reclaims == 0          # only bit-exact paths fired
    assert len(tight) == 4
    assert tight == ample                   # greedy output unchanged


def test_forced_reclaim_drains(setup):
    """Worst case: every tenant stalls on the SAME boundary step, all
    pages end up held by detached requests, and nothing can run.  The
    engine must force-reclaim a holder (re-prefill its context) and
    still drain everyone to their full token budget — liveness, not
    bit-exactness, is the contract on this escape hatch."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=4, max_len=64, prefill_buckets=(8,),
        kv_block_size=16, kv_pool_blocks=4))
    for uid in range(4):
        eng.submit(_req(uid, n=6, max_new_tokens=30))
    done = eng.run_until_drained()
    assert len(done) == 4
    assert all(len(r.generated) == 30 for r in done)
    assert eng.exhaust_preempts > 0 and eng.reclaims > 0
    assert eng.pool.num_free == eng.pool.num_blocks   # nothing leaked


def test_drop_saved_folds_generated_once(setup):
    """A request force-reclaimed TWICE must not see its first batch of
    generated tokens duplicated in the replayed context."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=2, max_len=64, prefill_buckets=(8,), kv_block_size=16))
    r = _req(0, n=4)
    base = list(r.prompt)
    r.generated = [7, 8]
    r.saved_state = {"blocks": [], "pos": 6, "pending": None, "last_tok": 8}
    eng._drop_saved(r)
    assert list(r.prompt) == base + [7, 8]
    r.generated = [7, 8, 9]          # one more token after re-admission
    r.saved_state = {"blocks": [], "pos": 7, "pending": None, "last_tok": 9}
    eng._drop_saved(r)
    assert list(r.prompt) == base + [7, 8, 9]   # no duplicated [7, 8]


def test_submit_rejects_request_larger_than_pool(setup):
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=2, max_len=64, prefill_buckets=(8,),
        kv_block_size=16, kv_pool_blocks=2))   # 32 tokens of pages
    with pytest.raises(ValueError, match="KV blocks"):
        eng.submit(_req(0, n=30, max_new_tokens=20))


def test_paged_matches_dense_engine_with_sampling(setup):
    """paged=True vs paged=False on mixed-length traffic (padded +
    chunked prefill) with temperature/top-k sampling: identical token
    streams — the block-table decode is bit-for-bit the dense path."""
    cfg, params = setup

    def run(paged):
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=3, max_len=96, prefill_buckets=(8, 16),
            temperature=0.8, top_k=8, seed=7, paged=paged))
        for uid, n in enumerate([5, 17, 33]):
            eng.submit(_req(uid, n=n, max_new_tokens=6))
        return {r.uid: tuple(r.generated) for r in eng.run_until_drained()}

    assert run(paged=True) == run(paged=False)


def test_block_tables_shrink_on_finish(setup):
    """Pages are released eagerly at _finish: after draining, the pool
    is back to fully free and every table row is cleared."""
    cfg, params = setup
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=2, max_len=64, prefill_buckets=(8,), kv_block_size=16))
    for uid in range(3):
        eng.submit(_req(uid, max_new_tokens=3))
    eng.run_until_drained()
    assert eng.pool.num_free == eng.pool.num_blocks
    assert (eng.block_tables == -1).all()


def test_ssm_and_hybrid_have_zero_pool_demand():
    """Families with no global KV layers run the dense path outright
    even when paged is requested — O(1)/ring state has nothing to page."""
    for arch in ("mamba2-370m", "zamba2-7b"):
        cfg = get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=2, max_len=64, prefill_buckets=(8,), paged=True))
        assert eng.paged is False and eng.pool is None
        eng.submit(_req(0, max_new_tokens=4))
        done = eng.run_until_drained()
        assert len(done) == 1 and len(done[0].generated) == 4
