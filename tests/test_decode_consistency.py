"""Prefill + decode must reproduce full-forward logits (per architecture).

MoE archs run with a large capacity factor (token dropping is the one
legitimate divergence); SSM families tolerate bf16 accumulation noise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, InputShape, get_smoke_config
from repro.models import model as M

SMOKE = InputShape("smoke", 32, 2, "train")
CUT = 8


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = M.specialize(get_smoke_config(arch), SMOKE)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=100.0)
    if cfg.family == "hybrid":
        cfg = cfg.replace(local_window=64)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = M.make_batch(cfg, SMOKE, key)

    pre = {k: (v[:, :CUT] if k in ("tokens", "targets") else v)
           for k, v in batch.items()}
    _, cache = M.prefill(cfg, params, pre, 48)
    n_img = cfg.num_image_tokens if cfg.family == "vlm" else 0
    pos = jnp.full((2,), n_img + CUT, jnp.int32)
    step_logits, cache = M.decode_step(cfg, params, cache,
                                       batch["tokens"][:, CUT:CUT + 1], pos)

    full_b = {k: (v[:, :CUT + 1] if k in ("tokens", "targets") else v)
              for k, v in batch.items()}
    full, _ = M.apply(cfg, params, full_b)
    a = np.asarray(step_logits[:, 0], np.float32)
    b = np.asarray(full[:, -1], np.float32)
    scale = max(1.0, float(np.abs(b).max()))
    assert np.abs(a - b).max() / scale < 0.05, \
        f"decode diverges from forward for {arch}"


def _reference_decode(cfg, params, prompt, max_new, extras, max_len):
    """Single-request greedy decode straight through the model API —
    unpadded prefill, then one decode_step per token."""
    batch = {"tokens": jnp.asarray(prompt)[None]}
    for k, v in extras.items():
        batch[k] = jnp.asarray(v)[None]
    logits, cache = M.prefill(cfg, params, batch, max_len)
    n_img = cfg.num_image_tokens if cfg.family == "vlm" else 0
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    pos = len(prompt) + n_img
    for _ in range(max_new - 1):
        lg, cache = M.decode_step(cfg, params, cache,
                                  jnp.asarray([[tok]], jnp.int32),
                                  jnp.full((1,), pos, jnp.int32))
        tok = int(jnp.argmax(lg[0, -1]))
        out.append(tok)
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["gemma3-1b", "mamba2-370m", "zamba2-7b",
                                  "granite-moe-1b-a400m", "whisper-base",
                                  "internvl2-76b"])
def test_padded_admission_matches_reference(arch):
    """Batched engine decode == sequential reference, token for token,
    for NON-bucket-aligned prompt lengths: 5 pads into the 8-bucket, 17
    pads into 32... except buckets stop at 16, so 17 and 33 exercise
    chunked prefill (catch-up through the decode wave) too.  This is the
    regression test for the off-by-bucket admission bug: position and
    admission logits must come from the true prompt length."""
    from repro.serving import EdgeServingEngine, Request, ServeConfig

    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=100.0)  # no token dropping
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def extras_for():
        e = {}
        if cfg.family == "encdec":
            e["audio_embeds"] = rng.normal(
                0, 0.1, (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            e["image_embeds"] = rng.normal(
                0, 0.1, (cfg.num_image_tokens, cfg.image_embed_dim)
            ).astype(np.float32)
        return e

    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=3, max_len=96, prefill_buckets=(8, 16)))
    reqs = []
    for uid, n in enumerate([5, 17, 33]):
        r = Request(uid=uid,
                    prompt=rng.integers(0, cfg.vocab_size, n,
                                        dtype=np.int32),
                    max_new_tokens=6, extras=extras_for())
        reqs.append(r)
        eng.submit(r)
    eng.run_until_drained()
    for r in reqs:
        ref = _reference_decode(cfg, params, r.prompt, 6, r.extras, 96)
        assert list(r.generated) == ref, \
            f"{arch} len={len(r.prompt)}: engine {r.generated} != ref {ref}"


@pytest.mark.parametrize("arch", ["gemma3-1b", "mamba2-370m", "zamba2-7b"])
def test_multi_step_decode(arch):
    """Three consecutive decode steps stay consistent with forward."""
    cfg = M.specialize(get_smoke_config(arch), SMOKE)
    if cfg.family == "hybrid":
        cfg = cfg.replace(local_window=64)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :8]}, 32)
    for t in range(8, 11):
        pos = jnp.full((2,), t, jnp.int32)
        lg, cache = M.decode_step(cfg, params, cache, toks[:, t:t + 1], pos)
        full, _ = M.apply(cfg, params, {"tokens": toks[:, :t + 1]})
        a = np.asarray(lg[:, 0], np.float32)
        b = np.asarray(full[:, -1], np.float32)
        scale = max(1.0, float(np.abs(b).max()))
        assert np.abs(a - b).max() / scale < 0.05
