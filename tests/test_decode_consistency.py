"""Prefill + decode must reproduce full-forward logits (per architecture).

MoE archs run with a large capacity factor (token dropping is the one
legitimate divergence); SSM families tolerate bf16 accumulation noise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, InputShape, get_smoke_config
from repro.models import model as M

SMOKE = InputShape("smoke", 32, 2, "train")
CUT = 8


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = M.specialize(get_smoke_config(arch), SMOKE)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=100.0)
    if cfg.family == "hybrid":
        cfg = cfg.replace(local_window=64)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = M.make_batch(cfg, SMOKE, key)

    pre = {k: (v[:, :CUT] if k in ("tokens", "targets") else v)
           for k, v in batch.items()}
    _, cache = M.prefill(cfg, params, pre, 48)
    n_img = cfg.num_image_tokens if cfg.family == "vlm" else 0
    pos = jnp.full((2,), n_img + CUT, jnp.int32)
    step_logits, cache = M.decode_step(cfg, params, cache,
                                       batch["tokens"][:, CUT:CUT + 1], pos)

    full_b = {k: (v[:, :CUT + 1] if k in ("tokens", "targets") else v)
              for k, v in batch.items()}
    full, _ = M.apply(cfg, params, full_b)
    a = np.asarray(step_logits[:, 0], np.float32)
    b = np.asarray(full[:, -1], np.float32)
    scale = max(1.0, float(np.abs(b).max()))
    assert np.abs(a - b).max() / scale < 0.05, \
        f"decode diverges from forward for {arch}"


@pytest.mark.parametrize("arch", ["gemma3-1b", "mamba2-370m", "zamba2-7b"])
def test_multi_step_decode(arch):
    """Three consecutive decode steps stay consistent with forward."""
    cfg = M.specialize(get_smoke_config(arch), SMOKE)
    if cfg.family == "hybrid":
        cfg = cfg.replace(local_window=64)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :8]}, 32)
    for t in range(8, 11):
        pos = jnp.full((2,), t, jnp.int32)
        lg, cache = M.decode_step(cfg, params, cache, toks[:, t:t + 1], pos)
        full, _ = M.apply(cfg, params, {"tokens": toks[:, :t + 1]})
        a = np.asarray(lg[:, 0], np.float32)
        b = np.asarray(full[:, -1], np.float32)
        scale = max(1.0, float(np.abs(b).max()))
        assert np.abs(a - b).max() / scale < 0.05
