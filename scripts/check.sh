#!/usr/bin/env bash
# Tier-1 gate: compat status, fast import sweep, then the test suite.
# The import sweep catches AxisType-style JAX version breaks in seconds
# instead of surfacing them as collection errors three minutes in.
#
#   scripts/check.sh          full gate: compat + imports + serving
#                             perf baseline + tier-1 suite; FAILS if any
#                             single test exceeds REPRO_TEST_TIME_LIMIT
#                             seconds (default 120 — keeps the growing
#                             suite tractable; see tests/conftest.py)
#   scripts/check.sh --fast   skip the benchmark gate; run tier-1 with
#                             --durations=15 and no per-test time limit
#                             (the quick inner-loop check)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FAST=0
for arg in "$@"; do
    case "$arg" in
        --fast) FAST=1 ;;
        *) echo "unknown flag: $arg (supported: --fast)" >&2; exit 2 ;;
    esac
done

echo "== compat ==" >&2
python scripts/diagnose.py --compat >&2

echo "== import sweep ==" >&2
python - <<'PY'
import importlib
MODULES = [
    "repro.compat",
    "repro.configs",
    "repro.core",
    "repro.data",
    "repro.kernels",
    "repro.launch",
    "repro.models",
    "repro.serving",
    "repro.training",
]
for mod in MODULES:
    importlib.import_module(mod)
    print(f"  ok {mod}")
PY

if [ "$FAST" = "1" ]; then
    echo "== tier-1 tests (fast: no benchmark gate) ==" >&2
    python -m pytest -x -q --durations=15
    exit 0
fi

echo "== serving perf baseline (incl. open-loop + quant capacity) ==" >&2
# the baseline gates the closed-loop QoE numbers AND the open-loop
# scenario (Poisson arrivals into a live engine): token counts exactly,
# plus chunked-prefill interleaving strictly beating monolithic-prefill
# stalls on decode inter-token p99, plus the int8-KV capacity scenario
# (capacity_* counters exact: page counts per layout, peak concurrency,
# the >=1.8x concurrency-gain bool and greedy-tolerance parity bool)
python -m benchmarks.serving_throughput --requests 12 \
    --check benchmarks/serving_baseline.json >&2

echo "== tier-1 tests ==" >&2
# any single test exceeding the limit fails the gate (slow-test creep
# is a regression too); override/disable with REPRO_TEST_TIME_LIMIT=0.
# 180 leaves headroom for the slowest pre-existing test
# (test_federated.py::test_full_private_pipeline measures 140-175s on
# the current reference host, code unchanged — the budget gates
# regressions, not hardware variance)
export REPRO_TEST_TIME_LIMIT="${REPRO_TEST_TIME_LIMIT-180}"
python -m pytest -x -q --durations=15
