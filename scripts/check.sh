#!/usr/bin/env bash
# Tier-1 gate: compat status, fast import sweep, then the test suite.
# The import sweep catches AxisType-style JAX version breaks in seconds
# instead of surfacing them as collection errors three minutes in.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compat ==" >&2
python scripts/diagnose.py --compat >&2

echo "== import sweep ==" >&2
python - <<'PY'
import importlib
MODULES = [
    "repro.compat",
    "repro.configs",
    "repro.core",
    "repro.data",
    "repro.kernels",
    "repro.launch",
    "repro.models",
    "repro.serving",
    "repro.training",
]
for mod in MODULES:
    importlib.import_module(mod)
    print(f"  ok {mod}")
PY

echo "== serving perf baseline ==" >&2
python -m benchmarks.serving_throughput --requests 12 \
    --check benchmarks/serving_baseline.json >&2

echo "== tier-1 tests ==" >&2
python -m pytest -x -q
