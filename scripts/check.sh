#!/usr/bin/env bash
# Tier-1 gate: compat status, fast import sweep, monotonic-clock static
# sweep, then the test suite.  The import sweep catches AxisType-style
# JAX version breaks in seconds instead of surfacing them as collection
# errors three minutes in.
#
#   scripts/check.sh          full gate: compat + imports + clock sweep
#                             + serving perf baseline + tier-1 suite;
#                             FAILS if any single test exceeds
#                             REPRO_TEST_TIME_LIMIT seconds (default
#                             120 — keeps the growing suite tractable;
#                             see tests/conftest.py)
#   scripts/check.sh --fast   skip the benchmark gate; run tier-1 with
#                             no per-test time limit (the quick
#                             inner-loop check)
#
# Both modes write check_summary.json (machine-readable: tier-1
# pass/fail/skip counts, baseline-gate verdict, slowest 5 tests) so CI
# and the growth driver can gate without scraping stdout.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FAST=0
for arg in "$@"; do
    case "$arg" in
        --fast) FAST=1 ;;
        *) echo "unknown flag: $arg (supported: --fast)" >&2; exit 2 ;;
    esac
done

echo "== compat ==" >&2
python scripts/diagnose.py --compat >&2

echo "== import sweep ==" >&2
python - <<'PY'
import importlib
MODULES = [
    "repro.compat",
    "repro.configs",
    "repro.core",
    "repro.data",
    "repro.kernels",
    "repro.launch",
    "repro.models",
    "repro.serving",
    "repro.training",
]
for mod in MODULES:
    importlib.import_module(mod)
    print(f"  ok {mod}")
PY

echo "== monotonic-clock static sweep ==" >&2
# serving/launch timing must route through serving/telemetry.py's
# default_clock (time.time() is not monotonic; scattering perf_counter
# defeats clock injection).  Only telemetry.py may touch time.* —
# fail on any new direct call in src/.
CLOCK_OFFENDERS=$(grep -rn --include='*.py' \
    -e 'time\.time()' -e 'time\.perf_counter()' -e 'time\.monotonic()' \
    src/ | grep -v 'src/repro/serving/telemetry\.py' || true)
if [ -n "$CLOCK_OFFENDERS" ]; then
    echo "direct clock calls outside telemetry.py:" >&2
    echo "$CLOCK_OFFENDERS" >&2
    exit 1
fi
echo "  ok (no direct time.time/perf_counter/monotonic in src/)" >&2

BASELINE_VERDICT="skipped"
if [ "$FAST" != "1" ]; then
    echo "== serving perf baseline (incl. open-loop + quant capacity) ==" >&2
    # the baseline gates the closed-loop QoE numbers AND the open-loop
    # scenario (Poisson arrivals into a live engine): token counts
    # exactly, plus chunked-prefill interleaving strictly beating
    # monolithic-prefill stalls on decode inter-token p99, plus the
    # int8-KV capacity scenario (capacity_* counters exact) and the
    # trace-neutrality leg (traced tokens == untraced tokens).
    if python -m benchmarks.serving_throughput --requests 12 \
        --check benchmarks/serving_baseline.json >&2; then
        BASELINE_VERDICT="pass"
    else
        BASELINE_VERDICT="fail"
        python scripts/_check_summary.py --junit "" \
            --baseline "$BASELINE_VERDICT" --out check_summary.json
        exit 1
    fi
    # any single test exceeding the limit fails the gate (slow-test
    # creep is a regression too); override with REPRO_TEST_TIME_LIMIT=0.
    # 180 leaves headroom for the slowest pre-existing test
    # (test_federated.py::test_full_private_pipeline measures 140-175s
    # on the current reference host, code unchanged — the budget gates
    # regressions, not hardware variance)
    export REPRO_TEST_TIME_LIMIT="${REPRO_TEST_TIME_LIMIT-180}"
    echo "== tier-1 tests ==" >&2
else
    echo "== tier-1 tests (fast: no benchmark gate) ==" >&2
fi

JUNIT="$(mktemp /tmp/check_junit.XXXXXX.xml)"
TESTS_OK=0
python -m pytest -x -q --durations=15 --junitxml="$JUNIT" || TESTS_OK=$?
python scripts/_check_summary.py --junit "$JUNIT" \
    --baseline "$BASELINE_VERDICT" --out check_summary.json
rm -f "$JUNIT"
exit "$TESTS_OK"
