"""Fold a pytest junit-xml report into ``check_summary.json`` — the
machine-readable verdict ``scripts/check.sh`` leaves behind so CI and
the growth driver can gate on tier-1 counts, the serving-baseline
verdict, and slow-test creep (slowest 5 tests) without scraping stdout.

  python scripts/_check_summary.py --junit report.xml \
      --baseline pass|fail|skipped --out check_summary.json
"""
import argparse
import json
import xml.etree.ElementTree as ET


def summarize(junit_path: str) -> dict:
    if not junit_path:
        return {"ran": False}
    root = ET.parse(junit_path).getroot()
    suite = root if root.tag == "testsuite" else root.find("testsuite")
    cases = []
    for tc in suite.iter("testcase"):
        status = "passed"
        if tc.find("failure") is not None:
            status = "failed"
        elif tc.find("error") is not None:
            status = "error"
        elif tc.find("skipped") is not None:
            status = "skipped"
        cases.append({
            "id": f"{tc.get('classname', '')}::{tc.get('name', '')}",
            "time_s": round(float(tc.get("time", 0.0)), 2),
            "status": status,
        })
    counts = {}
    for c in cases:
        counts[c["status"]] = counts.get(c["status"], 0) + 1
    return {
        "ran": True,
        "total": len(cases),
        "passed": counts.get("passed", 0),
        "failed": counts.get("failed", 0) + counts.get("error", 0),
        "skipped": counts.get("skipped", 0),
        "slowest": sorted(cases, key=lambda c: -c["time_s"])[:5],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--junit", required=True,
                    help="pytest --junitxml output ('' = tests not run)")
    ap.add_argument("--baseline", required=True,
                    choices=("pass", "fail", "skipped"),
                    help="serving-baseline gate verdict")
    ap.add_argument("--out", default="check_summary.json")
    args = ap.parse_args()
    out = {"baseline_gate": args.baseline, "tier1": summarize(args.junit)}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {args.out}: baseline={args.baseline} "
          f"tier1={out['tier1'].get('passed', '-')}p/"
          f"{out['tier1'].get('failed', '-')}f/"
          f"{out['tier1'].get('skipped', '-')}s")


if __name__ == "__main__":
    main()
