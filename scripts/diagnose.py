import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimb diagnostics: compile one (arch x shape) and print the top
ops by bytes / flops / collective bytes (trip-scaled, per chip).

  PYTHONPATH=src python scripts/diagnose.py <arch> <shape> [top]
  PYTHONPATH=src python scripts/diagnose.py --compat   # JAX/shim status
  PYTHONPATH=src python scripts/diagnose.py --spec [verify] [draft] \
      [gamma] [max_len]   # draft/verify speculative compatibility
  PYTHONPATH=src python scripts/diagnose.py --cache [store.npz]
      # per-arch prefix-sharing capability; with a path, also a
      # persisted prefix-store report (header + per-chain summary)
  PYTHONPATH=src python scripts/diagnose.py --server [arch]
      # step-driven serving introspection: wave-budget plans,
      # live-slot frontier table, frontend SLO counters
  PYTHONPATH=src python scripts/diagnose.py --quant
      # per-arch quantization surface (int8 KV-poolable? draft-weight
      # quantizable?) + fused dequant kernel vs reference parity verdict
  PYTHONPATH=src python scripts/diagnose.py --trace trace.json
      # summarize a serving trace dump (launch.serve --trace /
      # engine.dump_chrome_trace): top phases by total time,
      # per-request TTFT decomposition table, spec acceptance by round

Exit codes (uniform across modes so CI can gate on any of them):
  0  report printed, all verdicts OK
  1  failure verdict — spec pairing incompatible (--spec), prefix
     store unreadable/corrupt (--cache), kernel parity FAIL (--quant),
     engine failed to drain or budget overshot (--server), trace
     invalid or structurally broken (--trace)
"""
import json
import sys

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import presets as pz
from repro.launch import specs as sp
from repro.launch.hlo_analysis import breakdown
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.training import optimizer as opt
from repro.training import trainer as tr


def spec_report(args: list) -> None:
    """Per-arch speculative capabilities + a draft/verify pairing
    verdict (vocab match, verify spec_decodable, gamma bounds) via the
    same ``validate_spec`` the engine enforces."""
    from repro.configs.registry import ARCH_IDS
    from repro.configs import get_smoke_config
    from repro.serving.spec_decode import validate_spec
    caps = {}
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        caps[arch] = {
            "family": cfg.family,
            "vocab": cfg.vocab_size,
            "extendable": M.extendable(cfg),       # multi-token catch-up
            "spec_decodable": M.spec_decodable(cfg),  # verify-capable
        }
    print("spec capabilities:", json.dumps(caps, indent=1))
    verify = args[0] if len(args) > 0 else "phi3-medium-14b"
    draft = args[1] if len(args) > 1 else "gemma3-1b"
    gamma = int(args[2]) if len(args) > 2 else 4
    max_len = int(args[3]) if len(args) > 3 else 256
    problems = validate_spec(get_smoke_config(verify),
                             get_smoke_config(draft), gamma, max_len)
    print(f"pairing verify={verify} draft={draft} gamma={gamma} "
          f"max_len={max_len}:")
    if problems:
        for p in problems:
            print(f"  INCOMPATIBLE: {p}")
        sys.exit(1)
    print("  ok (vocab match, verify spec_decodable, gamma in bounds)")


def cache_report(args: list) -> None:
    """Prefix-sharing capability per arch + (optionally) a persisted
    prefix-store report: validates the header the same way the engine
    does at rehydrate time and summarizes the stored chains."""
    from repro.configs.registry import ARCH_IDS
    from repro.configs import get_smoke_config
    caps = {arch: {"family": get_smoke_config(arch).family,
                   "prefix_sharable": M.prefix_sharable(
                       get_smoke_config(arch))}
            for arch in ARCH_IDS}
    print("prefix-sharing capabilities:", json.dumps(caps, indent=1))
    if not args:
        return
    import numpy as np
    path = args[0]
    try:
        with np.load(path) as data:
            meta = json.loads(bytes(data["meta"]).decode())
            n = int(data["n_chains"])
            chains = []
            total_blocks = 0
            for i in range(n):
                key = data[f"key_{i}"]
                nb = data[f"pages_{i}_0"].shape[1] if n else 0
                total_blocks += nb
                chains.append({"namespace": int(data[f"ns_{i}"]),
                               "tokens": int(len(key)), "blocks": int(nb)})
    except Exception as e:   # same operator-facing verdict as the engine
        print(f"prefix store {path}: UNREADABLE/CORRUPT ({e!r}) — an "
              "engine pointed at it will reject it and start cold")
        sys.exit(1)
    print(f"prefix store {path}:")
    print("  header:", json.dumps(meta))
    print(f"  chains: {n}, total blocks: {total_blocks}")
    for i, c in enumerate(chains[:16]):
        print(f"  chain {i}: {c['tokens']} tokens / {c['blocks']} pages "
              f"(namespace {c['namespace']})")
    if n > 16:
        print(f"  ... and {n - 16} more")


def quant_report(args: list) -> None:
    """Quantization surface per arch + a kernel parity verdict.

    Table: does the family hold int8-poolable KV pages (probed the same
    way the engine builds its pool — ``init_paged_cache`` with
    ``kv_dtype="int8"`` then checking for scale leaves), and are its
    weights draft-quantizable (``quantize_matmul_params`` finds matmul
    leaves to rewrite)?  Then the fused dequant paged-attention kernels
    (decode + extend) are run against the gather+dequant reference and
    the max logit error becomes the operator-facing verdict; exits 1 on
    parity failure so CI can gate on it.
    """
    del args
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import ARCH_IDS
    from repro.configs import get_smoke_config
    from repro.kernels import flash_attention as FA
    from repro.kernels import ref as R
    from repro.models import layers as L

    def has_quant_pages(tree) -> bool:
        if isinstance(tree, dict):
            if L.kv_pages_quantized(tree):
                return True
            return any(has_quant_pages(v) for v in tree.values())
        return False

    def count_quant_leaves(tree) -> int:
        if isinstance(tree, dict):
            if "q" in tree and "scale" in tree:
                return 1
            return sum(count_quant_leaves(v) for v in tree.values())
        return 0

    caps = {}
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        pages = M.init_paged_cache(cfg, 1, 32, num_blocks=4,
                                   block_size=8, kv_dtype="int8")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        qp = L.quantize_matmul_params(params)
        caps[arch] = {"family": cfg.family,
                      "kv_poolable_int8": has_quant_pages(pages),
                      "draft_quant_leaves": count_quant_leaves(qp)}
    print("quantization surface:", json.dumps(caps, indent=1))

    # --- fused dequant kernel vs gather+dequant reference --------------
    key = jax.random.PRNGKey(7)
    B, H, K, hd, nB, bs, n_blk, S = 2, 4, 2, 64, 12, 8, 3, 4
    ks = jax.random.split(key, 5)
    kf = jax.random.normal(ks[0], (nB, bs, K, hd), jnp.float32)
    vf = jax.random.normal(ks[1], (nB, bs, K, hd), jnp.float32)
    kq, ksc = L.quantize_kv(kf)
    vq, vsc = L.quantize_kv(vf)
    bt = jnp.arange(B * n_blk, dtype=jnp.int32).reshape(B, n_blk)
    pos = jnp.asarray([bs * n_blk - 1, 13], jnp.int32)
    scale = hd ** -0.5
    qd = jax.random.normal(ks[2], (B, H, hd), jnp.float32)
    out_k = FA.paged_attention(qd, kq, vq, bt, pos, scale=scale,
                               k_scale=ksc, v_scale=vsc, interpret=True)
    out_r = R.paged_attention_ref(qd, kq, vq, bt, pos, scale=scale,
                                  k_scale=ksc, v_scale=vsc)
    err_d = float(jnp.max(jnp.abs(out_k - out_r)))
    qe = jax.random.normal(ks[3], (B, S, H, hd), jnp.float32)
    kn = jax.random.normal(ks[4], (B, S, K, hd), jnp.float32)
    vn = jax.random.normal(ks[0], (B, S, K, hd), jnp.float32)
    ext_k = FA.paged_extend_attention(qe, kq, vq, kn, vn, bt, pos,
                                      scale=scale, k_scale=ksc,
                                      v_scale=vsc, interpret=True)
    ext_r = R.paged_extend_attention_ref(qe, kq, vq, kn, vn, bt, pos,
                                         scale=scale, k_scale=ksc,
                                         v_scale=vsc)
    err_e = float(jnp.max(jnp.abs(ext_k - ext_r)))
    tol = 2e-5
    ok = err_d < tol and err_e < tol
    print(f"fused dequant kernel parity: decode err {err_d:.2e}, "
          f"extend err {err_e:.2e}, tol {tol:.0e} -> "
          f"{'OK' if ok else 'FAIL'}")
    if not ok:
        sys.exit(1)


def server_report(args: list) -> None:
    """Step-driven serving introspection: drive a live chunked engine a
    few waves and print each wave's budget plan (slot -> mode x width),
    the live-slot frontier table mid-flight, then finish the trace
    through the always-on frontend and report its SLO counters."""
    import asyncio

    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.launch.serve import AsyncServingFrontend
    from repro.serving import EdgeServingEngine, Request, ServeConfig

    arch = args[0] if args else "phi3-medium-14b"
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=3, max_len=96, prefill_buckets=(8, 16, 32),
        chunked_prefill=True, catch_chunk=4, wave_tokens=10))
    rng = np.random.default_rng(0)

    def req(uid, n):
        return Request(uid=uid,
                       prompt=rng.integers(0, cfg.vocab_size, n,
                                           dtype=np.int32),
                       max_new_tokens=8)

    for uid, n in enumerate((30, 6, 12)):   # one catch-up + two short
        eng.submit(req(uid, n))
    print(f"wave-budget plans ({arch}, wave_tokens=10, catch_chunk=4):")
    overshoots = []
    for i in range(4):
        eng.step()
        plan = {s: f"{m}x{v}" for s, (m, v) in sorted(eng.last_plan.items())}
        fed = sum(v for _, v in eng.last_plan.values())
        if fed > eng.scfg.wave_tokens:
            overshoots.append((i, fed))
        print(f"  wave {i}: {json.dumps(plan)}")
    print("live-slot frontier:")
    print("  slot uid   pos pending published mode")
    for s in range(eng.scfg.max_slots):
        r = eng.slot_req[s]
        if r is None or not eng.active[s]:
            continue
        pend = 0 if eng.pending[s] is None else len(eng.pending[s])
        mode = eng.last_plan.get(s, ("-", 0))[0]
        print(f"  {s:4d} {r.uid:3d} {int(eng.pos[s]):5d} {pend:7d} "
              f"{eng.slot_published[s]:9d} {mode}")

    async def finish():
        fe = AsyncServingFrontend(eng)
        await fe.start()
        fe.submit(req(10, 9))
        fe.submit(req(11, 21))
        await fe.shutdown()                  # drains everything live
        return fe.slo_stats(ttft_slo_ms=500.0, itl_slo_ms=50.0)

    print("frontend SLO counters:", json.dumps(asyncio.run(finish())))
    st = eng.stats()
    print("engine:", json.dumps({k: st[k] for k in
                                 ("steps", "mixed_waves", "wave_admitted",
                                  "cancels")}))
    # operator verdict: every submitted request drained, no wave ever
    # exceeded its token budget
    done = sorted(r.uid for r in eng.completed)
    expect = [0, 1, 2, 10, 11]
    ok = done == expect and not overshoots
    print(f"server verdict: drained {done} (expect {expect}), "
          f"budget overshoots {overshoots} -> {'OK' if ok else 'FAIL'}")
    if not ok:
        sys.exit(1)


def trace_report(args: list) -> None:
    """Summarize a Chrome-trace dump produced by
    ``launch.serve --trace`` / ``engine.dump_chrome_trace``: validity
    verdict, top phases by total time, per-request TTFT decomposition,
    and speculative acceptance by round.  Exits 1 when the file is
    unreadable or structurally invalid (missing ph/ts/pid/tid,
    unbalanced B/E spans)."""
    from repro.serving.telemetry import summarize_trace

    if not args:
        print("usage: diagnose.py --trace <trace.json>")
        sys.exit(1)
    path = args[0]
    try:
        with open(path) as f:
            trace = json.load(f)
    except Exception as e:
        print(f"trace {path}: UNREADABLE ({e!r})")
        sys.exit(1)
    s = summarize_trace(trace)
    n_ev = len(trace.get("traceEvents", []))
    print(f"trace {path}: {n_ev} events, "
          f"{len(s['requests'])} requests")
    print("top phases by total time:")
    print("  phase             total_ms   calls   mean_us")
    for p in s["phases"][:10]:
        print(f"  {p['name']:<16s} {p['total_us'] / 1e3:9.3f} "
              f"{p['calls']:7d} {p['mean_us']:9.1f}")
    if s["requests"]:
        print("per-request TTFT decomposition (ms):")
        print("  uid    queue  prefill  first_wave    ttft     e2e  toks")
        for r in s["requests"]:
            def ms(v):
                return "     -" if v is None else f"{v / 1e3:6.2f}"
            print(f"  {r['uid']:3d} {ms(r['queue_wait_us'])} "
                  f"{ms(r['prefill_us'])}   {ms(r['first_wave_us'])} "
                  f" {ms(r['ttft_us'])} {ms(r['e2e_us'])} "
                  f"{r['n_tokens']:5d}")
    if s["accept_by_round"]:
        print("spec acceptance by round position:")
        for j, row in s["accept_by_round"].items():
            print(f"  round[{j}]: {row['accepted']}/{row['proposed']} "
                  f"accepted ({row['rate']:.2f})")
    if s["problems"]:
        for p in s["problems"][:20]:
            print(f"  INVALID: {p}")
        print(f"trace verdict: FAIL ({len(s['problems'])} problems)")
        sys.exit(1)
    print("trace verdict: OK")


def main():
    from repro.compat import report
    print("compat:", json.dumps(report()))
    if "--trace" in sys.argv:
        trace_report([a for a in sys.argv[1:] if not a.startswith("-")])
        return
    if "--quant" in sys.argv:
        quant_report([a for a in sys.argv[1:] if not a.startswith("-")])
        return
    if "--server" in sys.argv:
        server_report([a for a in sys.argv[1:] if not a.startswith("-")])
        return
    if "--cache" in sys.argv:
        cache_report([a for a in sys.argv[1:] if not a.startswith("-")])
        return
    if "--spec" in sys.argv:
        spec_report([a for a in sys.argv[1:] if not a.startswith("-")])
        return
    if "--compat" in sys.argv or len(sys.argv) < 3:
        return
    arch, shape_name = sys.argv[1], sys.argv[2]
    top = int(sys.argv[3]) if len(sys.argv) > 3 else 10
    preset_name = sys.argv[4] if len(sys.argv) > 4 else "baseline"
    preset = (pz.baseline if preset_name == "baseline" else pz.optimized)(arch)
    shape = INPUT_SHAPES[shape_name]
    cfg = M.specialize(get_config(arch), shape).replace(
        param_dtype=preset.param_dtype,
        moe_rowwise=getattr(preset, "moe_rowwise", False))
    mesh = make_production_mesh()
    tcfg = tr.TrainConfig(
        optimizer=opt.OptimizerConfig(moments_dtype=preset.moments_dtype),
        microbatches=preset.microbatches, remat=preset.remat)
    built = sp.build(cfg, shape, mesh, tcfg=tcfg, fsdp=preset.fsdp,
                     smart=preset.smart)
    compiled = built.fn.lower(*built.args).compile()
    bd = breakdown(compiled.as_text(), top=top)
    for section in ("by_coll", "by_bytes", "by_flops"):
        print(f"\n==== {section} ====")
        for r in bd[section]:
            key = {"by_coll": "coll_bytes", "by_bytes": "bytes",
                   "by_flops": "flops"}[section]
            print(f"  {r[key]:.3e}  x{r['scale']:<6.0f} {r['opcode']:<22s} "
                  f"{r['shape']:<40s} {r['meta']}")


if __name__ == "__main__":
    main()
