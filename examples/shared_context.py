"""Shared context demo (paper §Shared context): a smart speaker and a
camera embed observations into ONE subspace; multiple downstream tasks
(user intent, intrusion detection) share the fused representation —
and the fusion stays robust when a sensor drops out.

  PYTHONPATH=src python examples/shared_context.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import context as CX
from repro.training import optimizer as opt


def make_data(key, n, cam_d=32, mic_d=16, classes=4):
    """Synthetic multi-view events: both sensors observe a shared latent."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    latent = jax.random.randint(k1, (n,), 0, classes)
    proto_cam = jax.random.normal(k2, (classes, cam_d))
    proto_mic = jax.random.normal(k3, (classes, mic_d))
    noise = 0.7
    cam = proto_cam[latent] + noise * jax.random.normal(k4, (n, cam_d))
    mic = proto_mic[latent] + noise * jax.random.normal(k1, (n, mic_d))
    return {"cam": cam, "mic": mic}, latent


def accuracy(params, task, views, labels):
    preds = jnp.argmax(CX.multiview_logits(params, task, views), -1)
    return float(jnp.mean(preds == labels))


def main():
    key = jax.random.PRNGKey(0)
    params = CX.init_context_space(key, {"cam": 32, "mic": 16},
                                   shared_dim=24, num_classes=4)
    CX.add_task_head(params, "intent", 4)
    CX.add_task_head(params, "intrusion", 4)

    views, labels = make_data(key, 512)
    test_views, test_labels = make_data(jax.random.PRNGKey(9), 256)

    grad = jax.jit(jax.grad(
        lambda p, v, y: CX.context_loss(p, "intent", v, y)))
    static = {k: params[k] for k in ("_key", "shared_dim", "hidden")}
    for step in range(150):
        g = grad({k: v for k, v in params.items() if k not in static},
                 views, labels)
        upd = opt.sgd_update(
            {k: v for k, v in params.items() if k not in static}, g, 0.1)
        params = {**upd, **static}

    both = accuracy(params, "intent", test_views, test_labels)
    cam_only = accuracy(params, "intent", {"cam": test_views["cam"]},
                        test_labels)
    mic_only = accuracy(params, "intent", {"mic": test_views["mic"]},
                        test_labels)
    print("multi-view intent accuracy:")
    print(f"  camera + microphone : {both:.2%}")
    print(f"  camera only (mic down): {cam_only:.2%}")
    print(f"  microphone only       : {mic_only:.2%}")
    print("-> fusion beats either sensor; partial availability degrades "
          "gracefully")

    # second task rides the same backbone (no per-device duplication)
    logits = CX.multiview_logits(params, "intrusion", test_views)
    print(f"\nsecond task ('intrusion') shares the backbone: logits "
          f"{logits.shape} from the same fused context")


if __name__ == "__main__":
    main()
