"""Quickstart: build a model from the registry, run a forward pass,
train a few steps, generate a few tokens.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, InputShape, get_smoke_config
from repro.data import DataConfig, data_iterator
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig
from repro.training.optimizer import OptimizerConfig
from repro.training.trainer import TrainConfig, train_loop


def main():
    print(f"registry: {len(ARCH_IDS)} architectures -> {list(ARCH_IDS)}\n")

    # 1. build a reduced gemma3 (5:1 local:global sliding-window stack)
    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    print(f"gemma3-1b (smoke): {M.count_params(params):,} params, "
          f"{cfg.num_layers} layers, window={cfg.local_window}")

    # 2. forward pass
    shape = InputShape("demo", seq_len=64, global_batch=4, kind="train")
    batch = M.make_batch(cfg, shape)
    logits, _ = M.apply(cfg, params, batch)
    print(f"forward: tokens{batch['tokens'].shape} -> logits{logits.shape}")

    # 3. short training run on the synthetic bigram stream
    tcfg = TrainConfig(optimizer=OptimizerConfig(
        learning_rate=3e-3, warmup_steps=5, total_steps=40), remat=None)
    it = data_iterator(cfg, shape, DataConfig(branching=2))
    state, hist = train_loop(cfg, tcfg, it, 40, log_every=10,
                             callback=lambda s, m: print(
                                 f"  step {s:3d} loss {m['loss']:.3f}"))
    print(f"loss: {hist[0]['loss']:.2f} -> {hist[-1]['loss']:.2f} "
          f"(chain entropy = {np.log(2):.2f})")

    # 4. serve it: greedy generation through the hub engine
    eng = EdgeServingEngine(cfg, state["params"],
                            ServeConfig(max_slots=2, max_len=64,
                                        prefill_buckets=(8,)))
    eng.submit(Request(uid=0, prompt=np.arange(5, dtype=np.int32),
                       max_new_tokens=8))
    done = eng.run_until_drained()
    print(f"generated: {done[0].generated}")


if __name__ == "__main__":
    main()
