"""Train a small LM end-to-end on the synthetic bigram corpus with the
full production stack: config registry -> sharding-rule jit (on the
local mesh) -> AdamW(+8-bit moments) -> checkpoint -> reload -> serve.

CPU-sized by default (a few M params, 200 steps); pass --big for a
~100M-param run if you have the cycles.

  PYTHONPATH=src python examples/train_small.py [--big] [--steps N]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import InputShape, get_smoke_config
from repro.data import DataConfig, data_iterator
from repro.launch import specs as sp
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training import trainer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true",
                    help="~100M params instead of CPU-friendly ~3M")
    ap.add_argument("--out", default="/tmp/edgeai_lm.npz")
    args = ap.parse_args()

    cfg = get_smoke_config("gemma3-1b")
    if args.big:
        cfg = cfg.replace(num_layers=12, pattern_period=3, d_model=768,
                          num_heads=12, num_kv_heads=4, head_dim=64,
                          d_ff=2048, vocab_size=32000, local_window=256)
    shape = InputShape("train", seq_len=128, global_batch=8, kind="train")
    tcfg = tr.TrainConfig(
        optimizer=opt.OptimizerConfig(learning_rate=1e-3, warmup_steps=20,
                                      total_steps=args.steps,
                                      moments_dtype="int8"),
        remat=None)

    mesh = make_local_mesh()
    built = sp.build_train(cfg, shape, mesh, tcfg)
    state = tr.init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    n = M.count_params(state["params"])
    print(f"model: {n/1e6:.1f}M params | mesh {dict(mesh.shape)} | "
          f"8-bit Adam moments")

    it = data_iterator(cfg, shape, DataConfig(branching=2))
    t0 = time.time()
    for step in range(args.steps):
        state, metrics = built.fn(state, next(it))
        if step % 25 == 0 or step == args.steps - 1:
            tps = (step + 1) * shape.global_batch * shape.seq_len \
                / (time.time() - t0)
            print(f"  step {step:4d} loss {float(metrics['loss']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({tps:.0f} tok/s)")

    ckpt.save(args.out, state["params"], {"arch": cfg.name})
    params = ckpt.restore(args.out, jax.tree.map(lambda x: x,
                                                 state["params"]))
    print(f"checkpoint round-trip via {args.out} OK")

    eng = EdgeServingEngine(cfg, params,
                            ServeConfig(max_slots=2, max_len=160,
                                        prefill_buckets=(8,)))
    eng.submit(Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                       max_new_tokens=12))
    done = eng.run_until_drained()
    print(f"serve check: generated {done[0].generated}")


if __name__ == "__main__":
    main()
