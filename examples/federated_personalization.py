"""Federated personalization at the consumer edge: FedAvg rounds across
household devices with DP clipping + Gaussian noise and secure
aggregation, gated by trust zones.  Shows global loss improving while
individual updates stay masked.

  PYTHONPATH=src python examples/federated_personalization.py
"""
import jax
import jax.numpy as jnp

from repro.configs import InputShape, get_smoke_config
from repro.data import DataConfig, data_iterator
from repro.models import model as M
from repro.training import federated as fed
from repro.training import optimizer as opt


def main():
    cfg = get_smoke_config("gemma3-1b")
    shape = InputShape("fl", 48, 4, "train")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # four household clients with non-IID shards (different seeds)
    clients = {c: [next(data_iterator(cfg, shape, DataConfig(seed=c,
                                                             branching=2)))
                   for _ in range(2)] for c in range(4)}
    eval_batch = clients[0][0]

    fcfg = fed.FedConfig(local_steps=2, local_lr=0.4, dp_clip=2.0,
                         dp_noise_multiplier=0.02, secure_aggregation=True)
    print("round | eval loss | update norm   (DP clip=2.0, noise=0.02, "
          "SecAgg on)")
    loss = float(M.loss_fn(cfg, params, eval_batch)[0])
    print(f"  init | {loss:9.3f} |")
    for r in range(5):
        params, info = fed.fed_round(cfg, fcfg, params, clients, r)
        loss = float(M.loss_fn(cfg, params, eval_batch)[0])
        print(f"  {r:4d} | {loss:9.3f} | {info['update_norm']:.3f}")

    # demonstrate the SecAgg property: a single masked update is garbage,
    # the sum of masked updates is exact
    delta = {"w": jnp.ones((6,))}
    masked = [fed.secagg_mask(delta, c, [0, 1, 2], 7) for c in range(3)]
    total = jax.tree.map(lambda *xs: sum(xs), *masked)
    print("\nSecAgg: one masked update:", masked[0]["w"][:3],
          "... (hides the 1s)")
    print("        sum of all masked :", total["w"][:3], "= 3 x exact")


if __name__ == "__main__":
    main()
