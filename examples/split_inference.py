"""Split computing demo (SPINN [24]): cut a dense LM between a phone and
the hub, ship int8 activations over a modelled wireless channel, and
compare against fully-local / fully-offloaded execution.

  PYTHONPATH=src python examples/split_inference.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.core.network import CHANNEL_CATALOGUE, MultiChannelLink
from repro.core.perf_model import DEVICE_CATALOGUE
from repro.core.split import choose_split, split_forward
from repro.models import model as M
from repro.models import transformer as T


def main():
    # ---- decision layer: where to cut, per channel quality -------------
    cfg = get_config("phi3-medium-14b")
    phone = DEVICE_CATALOGUE["mid-phone"]
    hub = DEVICE_CATALOGUE["edgeai-hub"]
    print(f"{cfg.name}: {cfg.num_layers} layers, "
          f"{cfg.param_count()/1e9:.1f}B params\n")
    print(f"{'channel':>12} | {'cut':>4} | {'device':>8} {'net':>8} "
          f"{'hub':>8} | total")
    for ch in ("ethernet", "wifi6", "wifi-legacy", "ble"):
        link = MultiChannelLink([CHANNEL_CATALOGUE[ch]])
        d = choose_split(cfg, phone, hub, link, batch=1, seq=128)
        print(f"{ch:>12} | {d.split:>4} | {d.device_s*1e3:7.1f}ms "
              f"{d.transfer_s*1e3:7.1f}ms {d.hub_s*1e3:7.1f}ms | "
              f"{d.total_s*1e3:7.1f}ms")

    # ---- execution layer: the split actually runs (reduced model) ------
    cfg_s = get_smoke_config("phi3-medium-14b")
    params = M.init_params(cfg_s, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg_s.vocab_size)
    full = T.forward(cfg_s, params, toks)
    print("\nexecution check (reduced model, int8 wire):")
    for cut in range(cfg_s.num_layers + 1):
        out, payload = split_forward(cfg_s, params, toks, cut, bits=8)
        err = float(jnp.abs(out - full).max())
        print(f"  cut@{cut}: payload={payload/1024:.1f}KiB "
              f"max_logit_err={err:.4f}")


if __name__ == "__main__":
    main()
