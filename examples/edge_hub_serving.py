"""END-TO-END DRIVER — a day in the life of an EdgeAI-Hub.

Serves a small LM to a household of devices with batched requests
through the continuous-batching engine, while the orchestrator
schedules a mixed multi-tenant workload (streaming upscales, background
photo classification, a federated personalization round) with
priorities, deadlines, trust zones and a device failure mid-way.

  PYTHONPATH=src python examples/edge_hub_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import InputShape, get_smoke_config
from repro.core import trustzones as tz
from repro.core.hub import EdgeAIHub
from repro.core.orchestrator import TaskSpec
from repro.data import DataConfig, data_iterator
from repro.models import model as M
from repro.serving import Request, ServeConfig
from repro.training import federated as fed
from repro.configs import get_config


def main():
    hub = EdgeAIHub.create(policy="edf")
    print("devices:", ", ".join(hub.registry.names()))

    # ------------------------------------------------------------------
    # 1. deploy an assistant LM on the hub and serve batched requests
    # ------------------------------------------------------------------
    cfg = get_smoke_config("gemma3-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = hub.deploy_model("assistant", cfg, params,
                           ServeConfig(max_slots=4, max_len=96,
                                       prefill_buckets=(8, 16)))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(10):
        hub.serve("assistant", Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab_size, 6 + uid % 8,
                                         dtype=np.int32),
            max_new_tokens=12, priority=(2 if uid % 3 == 0 else 0)))
    done = eng.run_until_drained()
    toks = sum(len(r.generated) for r in done)
    print(f"[serving] {len(done)} requests, {toks} tokens in "
          f"{eng.steps} decode waves ({toks/(time.time()-t0):.0f} tok/s "
          f"on CPU)")

    # ------------------------------------------------------------------
    # 2. multi-tenant QoE scheduling with a mid-run device failure
    # ------------------------------------------------------------------
    full = get_config("gemma3-1b")
    for i in range(12):  # streaming upscale frames — tight deadlines
        hub.submit(TaskSpec(kind="stream", model=full, batch=1, seq=256,
                            priority=5, deadline_rel=0.2, arrival=i * 0.05,
                            source_device="living-room-tv"))
    for i in range(4):   # background gallery classification
        hub.submit(TaskSpec(
            kind="inference", model=full, batch=32, seq=1024, priority=0,
            deadline_rel=30.0, arrival=i * 0.1,
            source_device="alice-phone",
            data=tz.DataItem("gallery", "household", "alice")))
    hub.orchestrator.fail_device("vacuum")   # fault tolerance, mid-flight
    report = hub.run()
    print(f"[scheduler] {report['completed']} tasks, "
          f"miss_rate={report['miss_rate']:.2f}, "
          f"p99={report['p99_latency_s']*1e3:.0f}ms, "
          f"preemptions={report['preemptions']}")

    # ------------------------------------------------------------------
    # 3. overnight federated personalization round (trust-zone gated)
    # ------------------------------------------------------------------
    shape = InputShape("fl", 32, 4, "train")
    clients = ["alice-phone", "bob-phone", "living-room-tv",
               "bob-old-phone"]
    client_data = {n: [next(data_iterator(cfg, shape, DataConfig(seed=i)))]
                   for i, n in enumerate(clients)}
    item = tz.DataItem("home-speech", "household", "alice")
    new_params, info = hub.federated_round(
        cfg, fed.FedConfig(local_steps=2, local_lr=0.3, dp_clip=1.0,
                           dp_noise_multiplier=0.05,
                           secure_aggregation=True),
        params, client_data, item)
    print(f"[federated] round over {len(info['clients'])} zone-eligible "
          f"clients (of {len(clients)} offered), update_norm="
          f"{info['update_norm']:.3f} — DP + SecAgg on")
    print("done.")


if __name__ == "__main__":
    main()
