"""Paper §Sustainability: early-exit networks preempt computation on
easy inputs.  Trains a small dense model + exit head briefly, then
sweeps the confidence threshold; derived: expected-FLOPs saved fraction.
"""
import time

import jax

from repro.configs import InputShape, get_smoke_config
from repro.core import earlyexit as EE
from repro.data import DataConfig, data_iterator
from repro.models import model as M
from repro.training import optimizer as opt


def bench():
    t0 = time.perf_counter()
    cfg = get_smoke_config("phi3-medium-14b")
    shape = InputShape("ee", 32, 8, "train")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    heads = EE.init_exit_heads(cfg, key, [0])
    # branching=1 => deterministic successor chain: a learnable
    # task where exit confidence can actually saturate
    it = data_iterator(cfg, shape, DataConfig(branching=1))

    # brief joint training so exits become confident on the easy chain
    def loss_fn(pe, batch):
        p, exits = pe
        h = {"exits": exits, "exit_layers": heads["exit_layers"]}
        return EE.exit_loss(cfg, p, h, batch)

    grad = jax.jit(jax.value_and_grad(loss_fn))
    last = None
    for _ in range(30):
        batch = next(it)
        l, (gp, ge) = grad((params, heads["exits"]), batch)
        params = opt.sgd_update(params, gp, 0.3)
        heads["exits"] = opt.sgd_update(heads["exits"], ge, 0.3)
        last = float(l)

    out = []
    toks = next(it)["tokens"]
    for thr in (0.5, 0.8, 0.95):
        rep = EE.serve_early_exit(cfg, params, heads, toks, threshold=thr)
        us = (time.perf_counter() - t0) * 1e6
        out.append((f"earlyexit.thr{thr}.flops_saved_frac", us,
                    rep.flops_saved_frac))
    out.append(("earlyexit.final_train_loss",
                (time.perf_counter() - t0) * 1e6, last))
    return out
