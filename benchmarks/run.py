"""Benchmark driver — one benchmark per paper claim/table (DESIGN.md
§Paper-claim validation map).  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only quant]
"""
import argparse
import sys
import traceback

from benchmarks import (
    early_exit,
    flops_trend,
    memory_traffic,
    quant_serving,
    scheduler_qoe,
    serving_throughput,
    split_inference,
    train_vs_infer_mem,
)

SUITES = {
    "flops_trend": flops_trend,
    "quant": quant_serving,
    "memtraffic": memory_traffic,
    "trainmem": train_vs_infer_mem,
    "split": split_inference,
    "earlyexit": early_exit,
    "qoe": scheduler_qoe,
    "serving": serving_throughput,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    chosen = (args.only.split(",") if args.only else list(SUITES))

    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        mod = SUITES[name]
        try:
            for n, us, derived in mod.bench():
                print(f"{n},{us:.1f},{derived:.6g}")
        except Exception:
            failures += 1
            print(f"{name}.FAILED,0,0")
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
