"""Paper claim: TinyBERT (255 MB fp32) on an 8 MB-cache Edge TPU is
dominated by off-chip accesses; memory, not compute, is the scaling
bottleneck (memory ~100x the energy of compute).

We reproduce the structure of the claim with our stack: for each
architecture, the roofline memory term vs compute term at decode on an
edge NPU with a small on-chip buffer; derived value = fraction of archs
that are memory-bound at the edge (paper predicts ~all).
"""
import time

from repro.configs import ARCH_IDS, get_config
from repro.core.perf_model import DEVICE_CATALOGUE, estimate, inference_cost

ENERGY_PER_FLOP = 0.4e-12      # J (MAC, scaled-down mobile process)
ENERGY_PER_DRAM_BYTE = 40e-12  # J — the paper's ~100x memory:compute gap


def bench():
    t0 = time.perf_counter()
    phone = DEVICE_CATALOGUE["mid-phone"]
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        cost = inference_cost(cfg, batch=1, seq=1, weight_bits=16)
        est = estimate(cost, phone)
        e_compute = cost.flops * ENERGY_PER_FLOP
        e_memory = cost.mem_bytes * ENERGY_PER_DRAM_BYTE
        rows.append((arch, est.bottleneck, e_memory / max(e_compute, 1e-12)))
    frac_membound = sum(r[1] == "memory" for r in rows) / len(rows)
    us = (time.perf_counter() - t0) * 1e6
    out = [("memtraffic.frac_archs_memory_bound_decode", us, frac_membound)]
    for arch, _, ratio in rows:
        out.append((f"memtraffic.{arch}.energy_mem_over_compute", us, ratio))
    return out
