"""Paper claim: 4-bit Llama-2-7B runs 7.2x faster on an M2 Max than a
Galaxy S23 — heterogeneity the hub absorbs by hosting the heavy model.

Two parts:
  1. kernel: wall-time of the int8 quant_matmul Pallas kernel vs the
     bf16 jnp matmul at an edge-LLM layer shape (CPU interpret mode —
     relative numbers are indicative only; the roofline terms are the
     hardware-grounded comparison).
  2. perf-model: decode latency of a 7B-class dense config (phi3-14b /2)
     at 4-bit vs 16-bit weights on each device tier -> the cross-device
     throughput ratio the paper reports.
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.perf_model import DEVICE_CATALOGUE, estimate, inference_cost
from repro.kernels import ops


def _time(fn, *args, reps=3):
    # one warm-up call (compile), blocked on whatever pytree it returns
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench():
    out = []
    # --- kernel micro-benchmark (small shape; interpret mode) ----------
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 512), jnp.bfloat16)
    w = jax.random.normal(key, (512, 512), jnp.float32) * 0.05
    wq, sc = ops.quantize_weights(w, 8)
    us_q = _time(lambda a: ops.quant_matmul(a, wq, sc), x)
    us_d = _time(lambda a: a @ w.astype(jnp.bfloat16), x)
    out.append(("quant.kernel_int8_us", us_q, us_q / max(us_d, 1e-9)))

    # --- fused dequant paged attention (the serving read path) ---------
    # int8 pages + per-row scales streamed straight into the flash loop
    # vs the same kernel on f32 pages: the HBM-traffic win the paged
    # engine sees per decode step at quant_kv="int8".
    B, H, K, hd, nB, bs, n_blk = 4, 8, 2, 64, 32, 16, 8
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kf = jax.random.normal(ks[1], (nB, bs, K, hd), jnp.float32)
    vf = jax.random.normal(ks[2], (nB, bs, K, hd), jnp.float32)
    from repro.models import layers as L
    kq8, ksc = L.quantize_kv(kf)
    vq8, vsc = L.quantize_kv(vf)
    bt = jnp.arange(B * n_blk, dtype=jnp.int32).reshape(B, n_blk)
    pos = jnp.full((B,), bs * n_blk - 1, jnp.int32)
    scale = hd ** -0.5
    us_fq = _time(lambda a: ops.paged_attention(
        a, kq8, vq8, bt, pos, scale=scale, k_scale=ksc, v_scale=vsc), q)
    us_ff = _time(lambda a: ops.paged_attention(
        a, kf, vf, bt, pos, scale=scale), q)
    out.append(("quant.paged_dequant_attn_us", us_fq,
                us_fq / max(us_ff, 1e-9)))

    # --- device-tier model: the paper's cross-SoC gap ------------------
    t0 = time.perf_counter()
    cfg = get_config("phi3-medium-14b")   # 14B-class stand-in
    hub = DEVICE_CATALOGUE["edgeai-hub"]
    flagship = DEVICE_CATALOGUE["flagship-phone"]
    mid = DEVICE_CATALOGUE["mid-phone"]
    lat = {}
    for name, dev in [("hub", hub), ("flagship", flagship), ("mid", mid)]:
        for bits in (16, 4):
            cost = inference_cost(cfg, batch=1, seq=1, weight_bits=bits)
            lat[(name, bits)] = estimate(cost, dev).latency_s
    us = (time.perf_counter() - t0) * 1e6
    # cross-device gap at 4-bit (paper: 7.2x M2-vs-S23)
    gap = lat[("mid", 4)] / lat[("hub", 4)]
    out.append(("quant.crossdevice_gap_4bit", us, gap))
    out.append(("quant.flagship_speedup_16to4", us,
                lat[("flagship", 16)] / lat[("flagship", 4)]))
    out.append(("quant.hub_decode_ms_4bit", us, lat[("hub", 4)] * 1e3))
    out.append(("quant.mid_decode_ms_4bit", us, lat[("mid", 4)] * 1e3))
    return out
