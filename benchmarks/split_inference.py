"""Paper §Shared compute: split/offloaded inference (SPINN-style).

Sweeps the cut point for a 14B dense model between a mid phone and the
hub over four channel qualities; derived values: the optimal cut and
its speedup vs fully-on-device for each channel.
"""
import time

from repro.configs import get_config
from repro.core.network import CHANNEL_CATALOGUE, MultiChannelLink
from repro.core.perf_model import DEVICE_CATALOGUE, estimate, inference_cost
from repro.core.split import choose_split


def bench():
    out = []
    cfg = get_config("phi3-medium-14b")
    phone = DEVICE_CATALOGUE["mid-phone"]
    hub = DEVICE_CATALOGUE["edgeai-hub"]
    for ch_name in ("ethernet", "wifi6", "wifi-legacy", "ble"):
        t0 = time.perf_counter()
        link = MultiChannelLink([CHANNEL_CATALOGUE[ch_name]])
        dec = choose_split(cfg, phone, hub, link, batch=1, seq=128)
        # fully-on-device reference = split at the last layer
        local = choose_split(cfg, phone, phone, link, batch=1, seq=128)
        local_t = max(local.total_s,
                      estimate(inference_cost(cfg, 1, 128), phone).latency_s)
        us = (time.perf_counter() - t0) * 1e6
        out.append((f"split.{ch_name}.best_cut_layer", us, dec.split))
        out.append((f"split.{ch_name}.latency_ms", us, dec.total_s * 1e3))
        out.append((f"split.{ch_name}.speedup_vs_local", us,
                    local_t / dec.total_s))
    return out
