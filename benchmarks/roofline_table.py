"""Render dry-run JSONL records into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_table results/*.jsonl
"""
import json
import sys


def load(paths):
    recs = {}
    for p in paths:
        with open(p) as f:
            for line in f:
                r = json.loads(line)
                key = (r.get("arch"), r.get("shape"), r.get("mesh"),
                       r.get("preset_name", "baseline"))
                recs[key] = r  # later runs win
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}µs"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def gib(x):
    return f"{x/2**30:.2f}"


def render(recs, mesh="single", preset="baseline"):
    rows = []
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    archs = sorted({k[0] for k in recs})
    print(f"\n### Roofline — mesh={mesh}, preset={preset}\n")
    print("| arch | shape | compute | memory | collective | bottleneck | "
          "useful | args GiB/chip | temp GiB/chip |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in archs:
        for shape in shapes:
            r = recs.get((arch, shape, mesh, preset))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {arch} | {shape} | — | — | — | "
                      f"SKIP: {r['reason']} | — | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {arch} | {shape} | — | — | — | "
                      f"{r['status'].upper()} | — | — | — |")
                continue
            print(f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                  f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                  f"**{r['bottleneck']}** | {r['useful_ratio']:.2f} | "
                  f"{gib(r['arg_bytes_per_chip'])} | "
                  f"{gib(r['temp_bytes_per_chip'])} |")


def main():
    paths = sys.argv[1:] or ["results/dryrun_baseline.jsonl"]
    recs = load(paths)
    meshes = sorted({k[2] for k in recs})
    presets = sorted({k[3] for k in recs})
    for preset in presets:
        for mesh in meshes:
            if any(k[2] == mesh and k[3] == preset for k in recs):
                render(recs, mesh, preset)


if __name__ == "__main__":
    main()
