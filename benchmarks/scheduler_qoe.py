"""Paper §Networking & scheduling: QoE under multi-tenancy — deadline
miss rate of fifo vs priority vs edf on a mixed consumer workload
(latency-critical streaming upscales + background photo classification).
Derived: miss rate per policy (edf should win).
"""
import time

from repro.core.scheduler import AITask, EdgeScheduler


def _workload():
    tasks = []
    uid = 0
    # 20 streaming frames: short, tight deadlines, high priority
    for i in range(20):
        tasks.append(dict(uid=uid, kind="stream", duration_s=0.030,
                          device="hub", priority=5, arrival=i * 0.040,
                          deadline=i * 0.040 + 0.120))
        uid += 1
    # 6 background gallery batches: long, lax deadlines
    for i in range(6):
        tasks.append(dict(uid=uid, kind="inference", duration_s=0.200,
                          device="hub", priority=0, arrival=i * 0.100,
                          deadline=i * 0.100 + 5.0))
        uid += 1
    return tasks


def bench():
    out = []
    for policy in ("fifo", "priority", "edf"):
        t0 = time.perf_counter()
        sched = EdgeScheduler(policy)
        for spec in _workload():
            sched.submit(AITask(**spec))
        sched.run()
        rep = sched.qoe_report()
        us = (time.perf_counter() - t0) * 1e6
        out.append((f"qoe.{policy}.miss_rate", us, rep["miss_rate"]))
        out.append((f"qoe.{policy}.p99_latency_ms", us,
                    rep["p99_latency_s"] * 1e3))
    return out
