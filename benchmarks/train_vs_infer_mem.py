"""Paper claim: on-device training needs ~16x the peak memory of
inference (SmallBERT: >8 GB train vs 1/16th for inference [14]).

Measured for real on our stack: XLA temp+argument memory of a compiled
train step vs a compiled forward pass for a reduced dense model.
Derived value: the train/infer peak-memory ratio.
"""
import time
from functools import partial

import jax

from repro.configs import InputShape, get_smoke_config
from repro.models import model as M
from repro.training import trainer as tr


def _peak_bytes(compiled) -> float:
    ma = compiled.memory_analysis()
    return float(ma.temp_size_in_bytes + ma.argument_size_in_bytes
                 + ma.output_size_in_bytes)


def bench():
    t0 = time.perf_counter()
    cfg = get_smoke_config("gemma2-9b").replace(num_layers=4)
    shape = InputShape("m", 128, 8, "train")
    batch_shape = M.batch_shapes(cfg, shape)

    # inference: forward only
    infer = jax.jit(lambda p, b: M.apply(cfg, p, b)[0])
    params_shape = jax.eval_shape(
        partial(M.init_params, cfg, jax.random.PRNGKey(0)))
    c_inf = infer.lower(params_shape, batch_shape).compile()

    # training: fwd+bwd+adam, no remat (the paper's on-device setting)
    tcfg = tr.TrainConfig(remat=None)
    state_shape = jax.eval_shape(
        partial(tr.init_train_state, cfg, tcfg, jax.random.PRNGKey(0)))
    step = tr.make_train_step(cfg, tcfg)
    c_tr = jax.jit(step).lower(state_shape, batch_shape).compile()

    ratio = _peak_bytes(c_tr) / max(_peak_bytes(c_inf), 1.0)
    us = (time.perf_counter() - t0) * 1e6
    return [
        ("trainmem.infer_peak_mb", us, _peak_bytes(c_inf) / 2**20),
        ("trainmem.train_peak_mb", us, _peak_bytes(c_tr) / 2**20),
        ("trainmem.train_over_infer_ratio", us, ratio),
    ]
