"""Paper Fig. 1: DNN FLOPs demand vs consumer-hardware OP/s supply.

Computes inference FLOPs/token for every assigned architecture and the
serving-latency envelope on each consumer-edge device tier — the
compute gap the EdgeAI-Hub paradigm exists to close.  Derived value:
max(model FLOPs/token) / (flagship phone OP/s) in ms/token.
"""
import time

from repro.configs import ARCH_IDS, get_config
from repro.core.perf_model import DEVICE_CATALOGUE, model_flops_per_token


def bench():
    t0 = time.perf_counter()
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        f_tok = model_flops_per_token(cfg)
        rows.append((arch, f_tok))
    phone = DEVICE_CATALOGUE["flagship-phone"]
    hub = DEVICE_CATALOGUE["edgeai-hub"]
    worst = max(f for _, f in rows)
    gap_phone_ms = worst / (phone.peak_flops * 0.4) * 1e3
    gap_hub_ms = worst / (hub.peak_flops * 0.4) * 1e3
    us = (time.perf_counter() - t0) * 1e6
    out = [("flops_trend.max_model_vs_phone_ms_per_tok", us, gap_phone_ms),
           ("flops_trend.max_model_vs_hub_ms_per_tok", us, gap_hub_ms)]
    for arch, f in rows:
        out.append((f"flops_trend.{arch}.gflops_per_tok", us, f / 1e9))
    return out
