"""Serving-path benchmark: tokens/sec and time-to-first-token under
mixed prompt-length multi-tenant traffic (the EdgeAI-Hub QoE numbers).

Workload: short chat turns, medium instructions and long documents in
one queue — prompt lengths deliberately NOT bucket-aligned, so this
exercises padded exact admission AND chunked (catch-up) prefill.
Derived values: aggregate generated tokens/sec, p50/p99 TTFT (submit ->
first generated token, queueing included).

  PYTHONPATH=src python -m benchmarks.serving_throughput [--requests N]
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig

ARCH = "gemma3-1b"
# (lo, hi) prompt-length bands of the traffic mix — 9..97 crosses every
# bucket boundary below and the largest band exceeds the largest bucket
_BANDS = ((4, 12), (20, 40), (70, 100))
_SCFG = ServeConfig(max_slots=4, max_len=192, prefill_buckets=(16, 32, 64),
                    policy="priority")


def _workload(n_requests: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        lo, hi = _BANDS[uid % len(_BANDS)]
        n = int(rng.integers(lo, hi + 1))
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(0, vocab, n, dtype=np.int32),
            max_new_tokens=16,
            priority=uid % 3))
    return reqs


def run(n_requests: int = 12, seed: int = 0) -> dict:
    cfg = get_smoke_config(ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = EdgeServingEngine(cfg, params, _SCFG)

    # warm the jit caches with the IDENTICAL workload: prefill variants
    # are cached per (bucket, batch, extras), and admission grouping is
    # deterministic, so replaying the same requests guarantees every
    # variant the measured run needs is already compiled — TTFT then
    # measures serving latency, not XLA compile time
    for r in _workload(n_requests, cfg.vocab_size, seed=seed):
        eng.submit(r)
    eng.run_until_drained()
    eng.completed.clear()
    eng.steps = 0

    reqs = _workload(n_requests, cfg.vocab_size, seed=seed)
    t_submit = {}
    t_first = {}
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
        t_submit[r.uid] = time.perf_counter()
    while eng.queue or eng.active.any():
        eng.step()
        now = time.perf_counter()
        for r in reqs:
            if r.uid not in t_first and r.generated:
                t_first[r.uid] = now
    elapsed = time.perf_counter() - t0

    toks = sum(len(r.generated) for r in eng.completed)
    ttft_ms = np.asarray(
        [(t_first[u] - t_submit[u]) * 1e3 for u in t_first])
    return {
        "requests": len(eng.completed),
        "decode_steps": eng.steps,
        "tokens": toks,
        "elapsed_s": elapsed,
        "tok_per_s": toks / elapsed,
        "ttft_p50_ms": float(np.percentile(ttft_ms, 50)),
        "ttft_p99_ms": float(np.percentile(ttft_ms, 99)),
    }


def bench():
    r = run()
    us = r["elapsed_s"] * 1e6
    return [
        ("serving.tok_per_s", us, r["tok_per_s"]),
        ("serving.ttft_p50_ms", us, r["ttft_p50_ms"]),
        ("serving.ttft_p99_ms", us, r["ttft_p99_ms"]),
    ]


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = run(args.requests, args.seed)
    out = {k: (round(v, 3) if isinstance(v, float) else v)
           for k, v in out.items()}
    print(json.dumps(out))


if __name__ == "__main__":
    main()
