"""Serving-path benchmark: tokens/sec and time-to-first-token under
mixed prompt-length multi-tenant traffic (the EdgeAI-Hub QoE numbers).

Workload: short chat turns, medium instructions and long documents in
one queue — prompt lengths deliberately NOT bucket-aligned, so this
exercises padded exact admission AND chunked (catch-up) prefill.
Derived values: aggregate generated tokens/sec, p50/p99 TTFT (submit ->
first generated token, queueing included), plus the paged-KV admission
numbers: peak concurrent requests and peak pool pages in flight, a
same-KV-byte-budget demo showing the paged engine admitting more
concurrent tenants than ``max_slots`` dense strips would allow, and a
shared-prefix scenario (N users, one household system prompt ending
MID-page, on a fully-paged arch) reporting radix prefix-cache hit-rate,
TTFT on cache hits vs a cold prefill, the token-granular hit-token
count vs the block-granular counterfactual (``shared_hit_tokens`` >
``shared_hit_tokens_block``), and a restart-warm leg (persist the hot
chains via ``ServeConfig.prefix_persist_path`` + ``engine.close()``,
rebuild the engine from the store, re-serve: ``persist_*`` fields +
``shared_ttft_warm_ms``), and a speculative-decoding scenario
(mixed traffic, verify=phi3 with a gemma3-1b cross draft AND the
early-exit self-draft) reporting tokens/sec, acceptance rate and mean
tokens per verify step against the non-speculative baseline — greedy
spec output is gated to be bit-identical to vanilla — and an OPEN-LOOP
scenario (Poisson arrivals, heavy-tailed lognormal prompt/output
lengths, no drain assumption) reporting TTFT/inter-token percentiles
and goodput under an SLO, with chunked-prefill interleaving gated to
strictly beat monolithic-prefill stalls on decode inter-token p99, and
a TELEMETRY leg (``ServeConfig.trace=True`` over the same workload)
gating trace neutrality: traced tokens bit-identical to untraced, a
structurally valid Chrome-trace dump, exact TTFT decomposition — with
the tracing overhead (wall-clock delta %) reported ungated.

  PYTHONPATH=src python -m benchmarks.serving_throughput [--requests N]
      [--write-baseline PATH] [--check PATH]

``--check`` compares against a committed baseline JSON: deterministic
fields (requests/tokens/decode_steps/concurrency) must match exactly —
any drift means the serving path changed behaviour — and tok_per_s must
stay above ``MIN_THROUGHPUT_RATIO`` x baseline (loose, to absorb shared
-CI timing noise while still catching order-of-magnitude regressions).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import EdgeServingEngine, Request, ServeConfig

ARCH = "gemma3-1b"
# fully-paged arch for the shared-prefix scenario (gemma's local-ring
# layers are not prefix-sharable — see model.prefix_sharable); also the
# speculative-decoding VERIFY model (spec needs model.spec_decodable)
SHARED_ARCH = "phi3-medium-14b"
# cross-model draft for the speculative scenario (any registry arch
# with a matching vocab; smoke configs all share vocab 512)
SPEC_DRAFT_ARCH = "gemma3-1b"
SPEC_GAMMA = 4
# (lo, hi) prompt-length bands of the traffic mix — 9..97 crosses every
# bucket boundary below and the largest band exceeds the largest bucket
_BANDS = ((4, 12), (20, 40), (70, 100))
_SCFG = ServeConfig(max_slots=4, max_len=192, prefill_buckets=(16, 32, 64),
                    policy="priority")
# perf-regression gate: fail --check below this fraction of baseline
# tok_per_s.  The committed baseline is machine-specific wall-clock, so
# the floor is overridable for slower hardware:
#   SERVING_BASELINE_MIN_RATIO=0.1 bash scripts/check.sh   (0 disables)
MIN_THROUGHPUT_RATIO = 0.25
# deterministic fields a baseline comparison must reproduce exactly
EXACT_FIELDS = ("requests", "decode_steps", "tokens", "peak_active",
                "demo_dense_slots", "demo_paged_concurrent",
                "shared_requests", "shared_hits", "shared_hit_blocks",
                "shared_tokens",
                # token-granular matching: total matched tokens must
                # strictly beat the PR-3 block-granular counterfactual
                "shared_hit_tokens", "shared_hit_tokens_block",
                # restart-warm (persisted prefix store) scenario
                "persist_chains", "persist_blocks", "persist_warm_hits",
                "persist_warm_tokens", "persist_warm_matches",
                # speculative scenario: greedy spec == vanilla bit-match
                # plus the (seed-deterministic) protocol counters
                "spec_requests", "spec_tokens", "spec_matches_vanilla",
                "spec_base_steps", "spec_cross_steps",
                "spec_cross_proposed", "spec_cross_accepted",
                "spec_self_steps", "spec_self_proposed",
                "spec_self_accepted",
                # open-loop: Poisson arrivals into a live engine; token
                # counts are step-schedule deterministic, and chunked
                # prefill must strictly beat monolithic-prefill stalls
                # on decode inter-token p99
                "openloop_requests", "openloop_tokens",
                "openloop_stall_tokens", "openloop_interleave_tokens",
                "openloop_stall_steps", "openloop_interleave_steps",
                "openloop_interleave_beats_stall",
                # int8 KV capacity: same pool BYTES, more pages, more
                # concurrent tenants — the quantization capacity claim
                # gated as exact counts, plus greedy-tolerance parity
                "capacity_requests", "capacity_f32_blocks",
                "capacity_int8_blocks", "capacity_f32_concurrent",
                "capacity_int8_concurrent", "capacity_gain_ok",
                "capacity_parity_ok",
                # telemetry: tracing must be behaviour-neutral (traced
                # tokens bit-identical to the untraced leg), the dump
                # structurally valid Chrome-trace JSON, and every
                # per-request TTFT decomposition must sum exactly
                "trace_requests", "trace_matches_untraced",
                "trace_valid", "trace_ttft_decomp_ok")


def _workload(n_requests: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        lo, hi = _BANDS[uid % len(_BANDS)]
        n = int(rng.integers(lo, hi + 1))
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(0, vocab, n, dtype=np.int32),
            max_new_tokens=16,
            priority=uid % 3))
    return reqs


def _admission_demo(cfg, params, seed: int = 0) -> dict:
    """Same-KV-byte-budget concurrency: a dense engine fits exactly
    ``dense_slots`` strips in the budget; the paged engine spends the
    SAME pages on actual tokens in flight and runs more tenants at
    once on mixed-length traffic."""
    dense_slots, max_len, bs = 2, 128, 16
    budget_blocks = dense_slots * (max_len // bs)
    eng = EdgeServingEngine(cfg, params, ServeConfig(
        max_slots=8, max_len=max_len, prefill_buckets=(16, 32),
        kv_block_size=bs, kv_pool_blocks=budget_blocks))
    rng = np.random.default_rng(seed)
    for uid in range(8):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               int(rng.integers(4, 14)),
                                               dtype=np.int32),
                           max_new_tokens=8))
    eng.run_until_drained()
    return {
        "demo_dense_slots": dense_slots,
        "demo_budget_blocks": budget_blocks,
        "demo_paged_concurrent": int(eng.peak_active),
        "demo_peak_pool_used": int(eng.peak_pool_used),
    }


def _shared_prefix_demo(seed: int = 0, n_users: int = 8) -> dict:
    """Household shared-prefix traffic: N users whose prompts start
    with the same system prompt.  The first user prefills it cold and
    its chain lands in the radix prefix cache; every later user HITS,
    shares the prefix pages by reference and prefills only its own
    tail — reported as cache hit-rate and TTFT cold vs hit (all
    variants pre-warmed on a throwaway system prompt, so the times are
    serving latency, not XLA compiles).

    The system prompt deliberately ends MID-page (45 tokens, 16-token
    pages) and every user tail opens with the same 5 assistant-persona
    tokens: a block-granular matcher would round each hit down to 32
    tokens, while token-granular matching serves 45 (and 50 once the
    first tail chain is indexed) — ``shared_hit_tokens`` vs
    ``shared_hit_tokens_block`` is that gain, gated exactly.

    A restart-warm variant then persists the warm cache to a store
    (``ServeConfig.prefix_persist_path`` + ``engine.close()``), builds
    a FRESH engine from it and re-serves a user: the hit must be
    bit-identical to the live-cache serve and ``shared_ttft_warm_ms``
    reports the restarted hub's TTFT."""
    import os
    import tempfile

    cfg = get_smoke_config(SHARED_ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    sys_warm = rng.integers(0, cfg.vocab_size, 45, dtype=np.int32)
    sys_meas = rng.integers(0, cfg.vocab_size, 45, dtype=np.int32)
    tail_common = rng.integers(0, cfg.vocab_size, 5, dtype=np.int32)

    def user(uid, sys_prompt):
        tail = np.random.default_rng(1000 + uid).integers(
            0, cfg.vocab_size, 3, dtype=np.int32)
        return Request(uid=uid, prompt=np.concatenate(
            [sys_prompt, tail_common, tail]), max_new_tokens=8)

    def serve(eng, req):
        """Submit + drain alone (clean TTFT, no queueing)."""
        t0 = time.perf_counter()
        eng.submit(req)
        ttft = None
        while eng.queue or eng.active.any():
            eng.drain_step()
            if ttft is None and req.generated:
                ttft = (time.perf_counter() - t0) * 1e3
        return ttft

    store = tempfile.NamedTemporaryFile(suffix=".npz", delete=False)
    store.close()
    try:
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=4, max_len=192, prefill_buckets=(16, 32, 64),
            prefix_cache=True, prefix_persist_path=store.name))
        # warm both compile variants (cold bucket + hit suffix bucket)
        serve(eng, user(900, sys_warm))
        serve(eng, user(901, sys_warm))
        h0, m0 = eng.prefix_cache.hits, eng.prefix_cache.misses
        hb0 = eng.prefix_cache.hit_blocks
        ht0 = eng.prefix_cache.hit_tokens
        htb0 = eng.prefix_cache.hit_tokens_block
        tok0 = sum(len(r.generated) for r in eng.completed)

        ttft_cold = serve(eng, user(0, sys_meas))
        hit_users = [user(uid, sys_meas) for uid in range(1, n_users)]
        ttft_hits = [serve(eng, u) for u in hit_users]
        eng.pool.assert_consistent()
        out = {
            "shared_requests": n_users,
            "shared_hits": eng.prefix_cache.hits - h0,
            "shared_misses": eng.prefix_cache.misses - m0,
            "shared_hit_blocks": eng.prefix_cache.hit_blocks - hb0,
            "shared_hit_tokens": eng.prefix_cache.hit_tokens - ht0,
            "shared_hit_tokens_block":
                eng.prefix_cache.hit_tokens_block - htb0,
            "shared_tokens": sum(len(r.generated)
                                 for r in eng.completed) - tok0,
            "shared_ttft_cold_ms": float(ttft_cold),
            "shared_ttft_hit_p50_ms": float(np.percentile(ttft_hits, 50)),
            "shared_ttft_hit_p99_ms": float(np.percentile(ttft_hits, 99)),
        }
        assert out["shared_hit_tokens"] > out["shared_hit_tokens_block"], (
            "token-granular matching must beat the block-granular "
            "baseline on this workload", out)

        # ---- restart-warm: persist, rebuild, re-serve ----------------
        saved = eng.close()
        warm_eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=4, max_len=192, prefill_buckets=(16, 32, 64),
            prefix_cache=True, prefix_persist_path=store.name))
        assert warm_eng.persist_rejected == "", warm_eng.persist_rejected
        serve(warm_eng, user(902, sys_warm))     # compile warm-up
        wh0 = warm_eng.prefix_cache.hits
        replay = user(1, sys_meas)
        ttft_warm = serve(warm_eng, replay)
        warm_eng.pool.assert_consistent()
        out.update({
            "persist_chains": int(saved["persist_saved_chains"]),
            "persist_blocks": int(saved["persist_saved_blocks"]),
            "persist_loaded_blocks": int(warm_eng.persist_loaded_blocks),
            "persist_warm_hits": int(warm_eng.prefix_cache.hits - wh0),
            "persist_warm_tokens": len(replay.generated),
            # restart-warm must reproduce the live-cache serve bitwise
            "persist_warm_matches": (tuple(replay.generated)
                                     == tuple(hit_users[0].generated)),
            "shared_ttft_warm_ms": float(ttft_warm),
        })
        return out
    finally:
        os.unlink(store.name)


def _spec_demo(seed: int = 0, n_requests: int = 12) -> dict:
    """Speculative decoding on mixed traffic: verify=phi3 (fully paged)
    with (a) a cross-model draft (gemma3-1b smoke — random weights, so
    acceptance is essentially the chance floor: the scenario is an
    upper bound on the PROTOCOL overhead) and (b) the early-exit
    self-draft (first half of the verify trunk — shared weights, real
    logit correlation, so acceptance and tokens/step are meaningfully
    above 1).  Both are greedily BIT-equal to the vanilla engine, which
    is gated as a deterministic baseline field."""
    cfg = get_smoke_config(SHARED_ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dcfg = get_smoke_config(SPEC_DRAFT_ARCH)
    dparams = M.init_params(dcfg, jax.random.PRNGKey(1))
    base_scfg = ServeConfig(max_slots=4, max_len=192,
                            prefill_buckets=(16, 32, 64),
                            spec_gamma=SPEC_GAMMA)
    spec_scfg = ServeConfig(max_slots=4, max_len=192,
                            prefill_buckets=(16, 32, 64),
                            spec_decode=True, spec_gamma=SPEC_GAMMA)

    def measure(scfg, draft=None):
        eng = EdgeServingEngine(cfg, params, scfg, draft=draft)
        for r in _workload(n_requests, cfg.vocab_size, seed=seed):
            eng.submit(r)
        eng.run_until_drained()          # warm every compile variant
        eng.completed.clear()
        eng.steps = eng.spec_steps = eng.spec_rounds = 0
        eng.spec_proposed = eng.spec_accepted = eng.spec_emitted = 0
        eng.reset_rng()
        t0 = time.perf_counter()
        for r in _workload(n_requests, cfg.vocab_size, seed=seed):
            eng.submit(r)
        eng.run_until_drained()
        elapsed = time.perf_counter() - t0
        eng.pool.assert_consistent()
        toks = {r.uid: tuple(r.generated) for r in eng.completed}
        return eng, elapsed, toks

    eng0, el0, base_toks = measure(base_scfg)
    engc, elc, cross_toks = measure(spec_scfg, draft=(dcfg, dparams))
    import dataclasses
    engs, els, self_toks = measure(
        dataclasses.replace(spec_scfg, draft_arch="self"))
    n_tok = sum(len(t) for t in base_toks.values())
    out = {
        "spec_requests": n_requests,
        "spec_tokens": n_tok,
        "spec_matches_vanilla": (cross_toks == base_toks
                                 and self_toks == base_toks),
        "spec_base_steps": eng0.steps,
        "spec_base_tok_per_s": n_tok / el0,
    }
    for tag, eng, el in (("cross", engc, elc), ("self", engs, els)):
        st = eng.stats()
        out.update({
            f"spec_{tag}_steps": eng.steps,
            f"spec_{tag}_proposed": st["spec_proposed"],
            f"spec_{tag}_accepted": st["spec_accepted"],
            f"spec_{tag}_accept_rate": st["spec_acceptance"],
            f"spec_{tag}_tokens_per_step": st["spec_tokens_per_round"],
            f"spec_{tag}_tok_per_s": n_tok / el,
        })
    return out


def _open_loop_demo(seed: int = 0, n_requests: int = 10) -> dict:
    """Open-loop serving: Poisson arrivals with heavy-tailed lognormal
    prompt/output lengths land in a LIVE engine (no drain assumption —
    arrival times are measured in engine steps, so the schedule is
    replay-deterministic).  Two legs over the same trace:

      stall      — chunked_prefill off, one big prefill bucket: a long
                   prompt's monolithic prefill rides the admission step
                   and every in-flight decode stalls behind it;
      interleave — chunked_prefill on: the prompt is consumed as
                   catch-up spans riding the shared wave budget, so
                   decode slots keep emitting every wave.

    Gated exactly: request/token counts per leg (greedy, step-schedule
    deterministic) and ``openloop_interleave_beats_stall`` — decode
    inter-token p99 must be strictly better with interleaving.  The
    stall leg's p99 gap *is* a prefill-inclusive step (256-token
    prefill vs a <=16-token wave, ~16x the compute, both legs fully
    compile-warmed on a replay of the identical trace), so the
    comparison is robust to timing noise.  TTFT/ITL percentiles and
    goodput under the SLO (TTFT p99 <= 500 ms AND inter-token p99 <=
    50 ms per request) are reported ungated — wall-clock is
    machine-specific."""
    cfg = get_smoke_config(SHARED_ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ttft_slo_ms, itl_slo_ms = 500.0, 50.0

    def traffic():
        rng = np.random.default_rng(seed + 77)
        reqs, arrive, t = [], [], 0.0
        for uid in range(n_requests):
            t += rng.exponential(2.0)           # Poisson, in step-time
            n = int(np.clip(rng.lognormal(4.2, 0.9), 6, 200))
            m = int(np.clip(rng.lognormal(2.6, 0.7), 4, 40))
            reqs.append(Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab_size, n, dtype=np.int32),
                max_new_tokens=m))
            arrive.append(int(t))
        return reqs, arrive

    def play(eng):
        """Drive the open-loop trace; per-request TTFT + token gaps."""
        reqs, arrive = traffic()
        pending = list(zip(reqs, arrive))
        seen = {r.uid: 0 for r in reqs}
        t_sub, t_last = {}, {}
        ttft = {r.uid: [] for r in reqs}
        gaps = {r.uid: [] for r in reqs}
        step_i = 0
        while pending or eng.queue or eng.active.any():
            while pending and pending[0][1] <= step_i:
                req, _ = pending.pop(0)
                eng.submit(req)
                t_sub[req.uid] = time.perf_counter()
            if not (eng.queue or eng.active.any()):
                step_i += 1                     # idle tick, next arrival
                continue
            eng.step()
            now = time.perf_counter()
            for r in reqs:
                if r.uid in t_sub and len(r.generated) > seen[r.uid]:
                    if seen[r.uid] == 0:
                        ttft[r.uid] = (now - t_sub[r.uid]) * 1e3
                    else:
                        gaps[r.uid].append((now - t_last[r.uid]) * 1e3)
                    t_last[r.uid] = now
                    seen[r.uid] = len(r.generated)
            step_i += 1
        return reqs, ttft, gaps

    def leg(tag, **chunk_kw):
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=4, max_len=256, prefill_buckets=(256,),
            prefix_cache=False, **chunk_kw))
        play(eng)                               # compile-warm every variant
        eng.completed.clear()
        eng.steps = 0
        eng.reset_rng()
        reqs, ttft, gaps = play(eng)
        eng.pool.assert_consistent()
        all_gaps = [g for r in reqs for g in gaps[r.uid]]
        good = sum(1 for r in reqs
                   if ttft[r.uid] <= ttft_slo_ms
                   and (not gaps[r.uid]
                        or np.percentile(gaps[r.uid], 99) <= itl_slo_ms))
        return {
            f"openloop_{tag}_tokens": sum(len(r.generated) for r in reqs),
            f"openloop_{tag}_steps": eng.steps,
            f"openloop_{tag}_ttft_p99_ms":
                float(np.percentile(list(ttft.values()), 99)),
            f"openloop_{tag}_itl_p50_ms": float(np.percentile(all_gaps, 50)),
            f"openloop_{tag}_itl_p99_ms": float(np.percentile(all_gaps, 99)),
            f"openloop_{tag}_goodput": good / len(reqs),
        }

    out = {"openloop_requests": n_requests,
           "openloop_ttft_slo_ms": ttft_slo_ms,
           "openloop_itl_slo_ms": itl_slo_ms}
    out.update(leg("stall"))
    out.update(leg("interleave", chunked_prefill=True, catch_chunk=8,
                   wave_tokens=16))
    out["openloop_tokens"] = (out["openloop_stall_tokens"]
                              + out["openloop_interleave_tokens"])
    out["openloop_interleave_beats_stall"] = bool(
        out["openloop_interleave_itl_p99_ms"]
        < out["openloop_stall_itl_p99_ms"])
    return out


def _capacity_demo(seed: int = 0, n_requests: int = 16) -> dict:
    """int8 KV capacity at a FIXED pool byte budget: the same HBM that
    holds 12 f32 pages holds ~45 int8(+scale) pages (3.76x at head_dim
    64 — ``kv_pool.page_bytes``), so the quantized engine runs ~4x the
    concurrent tenants on identical traffic.  Gated exactly: page
    counts per layout, peak concurrency per leg, the >= 1.8x
    concurrency-gain acceptance bool, and a greedy-tolerance parity
    bool (int8 tokens must track the f32 leg for >= 60% of positions by
    longest-common-prefix — quantized decode is NOT bit-exact, but it
    must be the same conversation).  TTFT / tok-s per leg are reported
    ungated (wall-clock)."""
    cfg = get_smoke_config(SHARED_ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    bs = 16
    from repro.serving.kv_pool import page_bytes, pool_blocks_for_budget
    budget = 12 * page_bytes(cfg, bs, None)     # exactly 12 f32 pages

    def traffic():
        rng = np.random.default_rng(seed + 5)
        return [Request(uid=uid,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            int(rng.integers(18, 30)),
                                            dtype=np.int32),
                        max_new_tokens=8)
                for uid in range(n_requests)]

    def leg(kv_dtype):
        blocks = pool_blocks_for_budget(cfg, bs, budget, kv_dtype)
        eng = EdgeServingEngine(cfg, params, ServeConfig(
            max_slots=n_requests, max_len=64, prefill_buckets=(16, 32),
            kv_block_size=bs, kv_pool_blocks=blocks, seed=9,
            prefix_cache=False, quant_kv=kv_dtype))
        for r in traffic():                     # compile-warm replay
            eng.submit(r)
        eng.run_until_drained()
        eng.completed.clear()
        eng.steps = eng.peak_active = eng.peak_pool_used = 0
        eng.reset_rng()
        reqs = traffic()
        t_sub, t_first = {}, {}
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
            t_sub[r.uid] = time.perf_counter()
        while eng.queue or eng.active.any():
            eng.drain_step()
            now = time.perf_counter()
            for r in reqs:
                if r.uid not in t_first and r.generated:
                    t_first[r.uid] = now
        elapsed = time.perf_counter() - t0
        eng.pool.assert_consistent()
        toks = {r.uid: tuple(r.generated) for r in eng.completed}
        n_tok = sum(len(t) for t in toks.values())
        ttft = [(t_first[u] - t_sub[u]) * 1e3 for u in t_first]
        return blocks, int(eng.peak_active), toks, {
            "tok_per_s": n_tok / elapsed,
            "ttft_p50_ms": float(np.percentile(ttft, 50)),
        }

    f32_blocks, f32_peak, f32_toks, f32_perf = leg(None)
    q_blocks, q_peak, q_toks, q_perf = leg("int8")
    lcp = total = 0
    for uid in f32_toks:
        a, b = f32_toks[uid], q_toks[uid]
        total += len(a)
        for x, y in zip(a, b):
            if x != y:
                break
            lcp += 1
    return {
        "capacity_requests": n_requests,
        "capacity_budget_bytes": budget,
        "capacity_f32_blocks": f32_blocks,
        "capacity_int8_blocks": q_blocks,
        "capacity_f32_concurrent": f32_peak,
        "capacity_int8_concurrent": q_peak,
        "capacity_gain_ok": bool(q_peak >= 1.8 * f32_peak),
        "capacity_parity_ok": bool(lcp >= 0.6 * total),
        "capacity_parity_lcp_frac": lcp / max(total, 1),
        "capacity_f32_tok_per_s": f32_perf["tok_per_s"],
        "capacity_int8_tok_per_s": q_perf["tok_per_s"],
        "capacity_f32_ttft_p50_ms": f32_perf["ttft_p50_ms"],
        "capacity_int8_ttft_p50_ms": q_perf["ttft_p50_ms"],
    }


def _trace_demo(seed: int = 0, n_requests: int = 12) -> dict:
    """Telemetry neutrality: the SAME mixed workload with
    ``ServeConfig.trace=True`` must emit bit-identical tokens to the
    untraced leg (the tracer only observes — its ``block_until_ready``
    fences are value-neutral), the Chrome-trace dump must be
    structurally valid (every event ph/ts/pid/tid, B/E balanced) and
    every request's queue_wait + prefill + first_wave must sum to its
    TTFT exactly (well under the 1 ms acceptance bound — the segments
    share boundary stamps).  Trace overhead (wall-clock delta %) is
    reported ungated: it is machine noise at this workload size, not a
    gate."""
    import dataclasses
    import json as _json
    import os
    import tempfile

    from repro.serving.telemetry import validate_chrome_trace

    cfg = get_smoke_config(ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def leg(trace):
        eng = EdgeServingEngine(cfg, params,
                                dataclasses.replace(_SCFG, trace=trace))
        for r in _workload(n_requests, cfg.vocab_size, seed=seed):
            eng.submit(r)
        eng.run_until_drained()              # compile-warm replay
        eng.completed.clear()
        eng.steps = 0
        eng.reset_rng()
        t0 = time.perf_counter()
        for r in _workload(n_requests, cfg.vocab_size, seed=seed):
            eng.submit(r)
        eng.run_until_drained()
        elapsed = time.perf_counter() - t0
        toks = {r.uid: tuple(r.generated) for r in eng.completed}
        return eng, elapsed, toks

    _, el_off, toks_off = leg(False)
    eng_on, el_on, toks_on = leg(True)

    tmp = tempfile.NamedTemporaryFile(suffix=".json", delete=False)
    tmp.close()
    try:
        dumped = eng_on.dump_chrome_trace(tmp.name)
        with open(tmp.name) as f:
            trace = _json.load(f)
    finally:
        os.unlink(tmp.name)
    problems = validate_chrome_trace(trace["traceEvents"])
    decomp_ok = True
    for row in eng_on.tracer.request_summaries():
        parts = (row["queue_wait_us"], row["prefill_us"],
                 row["first_wave_us"], row["ttft_us"])
        if None in parts or abs(sum(parts[:3]) - parts[3]) > 1000.0:
            decomp_ok = False
    return {
        "trace_requests": n_requests,
        "trace_matches_untraced": toks_on == toks_off,
        "trace_valid": not problems,
        "trace_ttft_decomp_ok": decomp_ok,
        "trace_events": int(dumped["events"]),
        "trace_overhead_pct": 100.0 * (el_on - el_off) / el_off,
    }


def run(n_requests: int = 12, seed: int = 0) -> dict:
    cfg = get_smoke_config(ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = EdgeServingEngine(cfg, params, _SCFG)

    # warm the jit caches with the IDENTICAL workload: prefill variants
    # are cached per (bucket, batch, extras), and admission grouping is
    # deterministic, so replaying the same requests guarantees every
    # variant the measured run needs is already compiled — TTFT then
    # measures serving latency, not XLA compile time
    for r in _workload(n_requests, cfg.vocab_size, seed=seed):
        eng.submit(r)
    eng.run_until_drained()
    eng.completed.clear()
    eng.steps = 0
    eng.peak_active = 0
    eng.peak_pool_used = 0
    # warmup advanced the sampling state (engine PRNG key + admission
    # rng); re-seed so a temperature>0 measured run samples exactly the
    # tokens a cold engine would — the benchmark is replay-deterministic
    eng.reset_rng()

    reqs = _workload(n_requests, cfg.vocab_size, seed=seed)
    t_submit = {}
    t_first = {}
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
        t_submit[r.uid] = time.perf_counter()
    while eng.queue or eng.active.any():
        eng.drain_step()   # step() + pool-wedge recovery (never spins)
        now = time.perf_counter()
        for r in reqs:
            if r.uid not in t_first and r.generated:
                t_first[r.uid] = now
    elapsed = time.perf_counter() - t0

    toks = sum(len(r.generated) for r in eng.completed)
    ttft_ms = np.asarray(
        [(t_first[u] - t_submit[u]) * 1e3 for u in t_first])
    out = {
        "requests": len(eng.completed),
        "decode_steps": eng.steps,
        "tokens": toks,
        "elapsed_s": elapsed,
        "tok_per_s": toks / elapsed,
        "ttft_p50_ms": float(np.percentile(ttft_ms, 50)),
        "ttft_p99_ms": float(np.percentile(ttft_ms, 99)),
        "peak_active": int(eng.peak_active),
        "peak_pool_used": int(eng.peak_pool_used),
        "pool_blocks": eng.pool.num_blocks if eng.paged else 0,
    }
    out.update(_admission_demo(cfg, params, seed))
    out.update(_shared_prefix_demo(seed))
    out.update(_spec_demo(seed, n_requests))
    out.update(_open_loop_demo(seed))
    out.update(_capacity_demo(seed))
    out.update(_trace_demo(seed, n_requests))
    return out


def compare_baseline(result: dict, baseline: dict,
                     min_ratio: float = None) -> list[str]:
    """Regression findings (empty list = pass).  The deterministic
    EXACT_FIELDS must match bit-for-bit (serving behaviour changed if
    not); the wall-clock floor only has to clear ``min_ratio`` x the
    baseline — set 0 to skip it on hardware unlike the baseline's."""
    import os
    if min_ratio is None:
        min_ratio = float(os.environ.get("SERVING_BASELINE_MIN_RATIO",
                                         MIN_THROUGHPUT_RATIO))
    problems = []
    # token streams are bit-stable per backend but not ACROSS backends
    # (bf16 matmul order can flip a greedy argmax tie): on hardware
    # unlike the baseline author's, skip the exact fields or regenerate
    # the baseline with --write-baseline
    skip_exact = os.environ.get("SERVING_BASELINE_SKIP_EXACT", "") == "1"
    for k in () if skip_exact else EXACT_FIELDS:
        if result.get(k) != baseline.get(k):
            problems.append(
                f"{k}: got {result.get(k)!r}, baseline {baseline.get(k)!r} "
                "(behaviour drift; if only the backend changed, set "
                "SERVING_BASELINE_SKIP_EXACT=1 or regenerate the baseline)")
    floor = baseline["tok_per_s"] * min_ratio
    if result["tok_per_s"] < floor:
        problems.append(
            f"tok_per_s {result['tok_per_s']:.1f} < {floor:.1f} "
            f"({min_ratio}x baseline {baseline['tok_per_s']:.1f}; "
            f"override with SERVING_BASELINE_MIN_RATIO)")
    return problems


def bench():
    r = run()
    us = r["elapsed_s"] * 1e6
    return [
        ("serving.tok_per_s", us, r["tok_per_s"]),
        ("serving.ttft_p50_ms", us, r["ttft_p50_ms"]),
        ("serving.ttft_p99_ms", us, r["ttft_p99_ms"]),
        ("serving.peak_active", us, r["peak_active"]),
        ("serving.shared_ttft_cold_ms", us, r["shared_ttft_cold_ms"]),
        ("serving.shared_ttft_hit_p50_ms", us,
         r["shared_ttft_hit_p50_ms"]),
        ("serving.shared_ttft_warm_ms", us, r["shared_ttft_warm_ms"]),
        ("serving.spec_self_tok_per_s", us, r["spec_self_tok_per_s"]),
        ("serving.spec_self_tokens_per_step", us,
         r["spec_self_tokens_per_step"]),
        ("serving.spec_self_accept_rate", us, r["spec_self_accept_rate"]),
    ]


def main() -> None:
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--write-baseline", metavar="PATH",
                    help="write result JSON to PATH (perf baseline)")
    ap.add_argument("--check", metavar="PATH",
                    help="compare against a baseline JSON; non-zero exit "
                         "on regression")
    args = ap.parse_args()
    out = run(args.requests, args.seed)
    rounded = {k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in out.items()}
    print(json.dumps(rounded))
    if args.write_baseline:
        with open(args.write_baseline, "w") as f:
            json.dump(rounded, f, indent=1, sort_keys=True)
            f.write("\n")
    if args.check:
        with open(args.check) as f:
            baseline = json.load(f)
        problems = compare_baseline(out, baseline)
        if problems:
            for p in problems:
                print(f"REGRESSION: {p}", file=sys.stderr)
            sys.exit(1)
        print(f"baseline check ok ({args.check})", file=sys.stderr)


if __name__ == "__main__":
    main()
